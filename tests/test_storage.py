"""Durable segment storage: Directory contracts, codec bit-identity
(hypothesis oracle), corruption detection, commit points and kill-9-style
recovery, measured media envelopes.

The acceptance invariants from the storage subsystem's contract:
  * encode -> decode is BIT-identical on randomized segments (including
    empty, single-posting-term, and max-doc-id edge cases);
  * corrupted/truncated files fail their checksum cleanly
    (``CorruptSegment``) instead of decoding garbage;
  * an interrupted run recovers to the last commit point with every
    committed doc searchable exactly once;
  * isolated source/target media beat the shared-media pair in the
    *measured* envelope (the paper's headline result, in silico).
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_arch
from repro.core.indexer import DistributedIndexer
from repro.core.searcher import ReaderCache
from repro.data.corpus import (TINY, SyntheticCorpus, iter_spooled,
                               spool_corpus)
from repro.storage import (MEDIA_PROFILES, CachingDirectory, CorruptSegment,
                           DeviceThrottle, FSDirectory, MediaProfile,
                           RAMDirectory, SegmentStore, ThrottledDirectory,
                           open_latest, open_searcher)
from repro.storage import codec as codec_mod
from repro.storage.codec import SEGMENT_SUFFIXES
from repro.storage.commit import (list_commits, manifest_name, read_commit,
                                  write_commit)
from test_merge import ARRAY_FIELDS, assert_bit_identical, make_segment

SMOKE_CFG = get_arch("lucene-envelope").smoke


@pytest.fixture(params=["ram", "fs", "fs-mmap"])
def directory(request, tmp_path):
    if request.param == "ram":
        return RAMDirectory()
    if request.param == "fs-mmap":
        return FSDirectory(tmp_path / "dir", mmap=True)
    return FSDirectory(tmp_path / "dir")


# ---------------------------------------------------------------------------
# Directory contract
# ---------------------------------------------------------------------------

def test_directory_basics(directory):
    assert directory.list_files() == []
    directory.write_file("a", b"hello")
    directory.write_file("b", b"world!!")
    assert directory.list_files() == ["a", "b"]
    assert directory.read_file("a") == b"hello"
    assert directory.file_size("b") == 7
    assert directory.file_exists("a") and not directory.file_exists("c")
    directory.rename("a", "c")
    assert directory.list_files() == ["b", "c"]
    assert directory.read_file("c") == b"hello"
    directory.delete_file("b")
    assert directory.list_files() == ["c"]
    with pytest.raises(FileNotFoundError):
        directory.read_file("zz")
    with pytest.raises(FileNotFoundError):
        directory.delete_file("zz")
    # measured-IO accounting
    assert directory.bytes_written == 12
    assert directory.bytes_read == 10  # "hello" twice
    directory.reset_counters()
    assert directory.bytes_written == directory.bytes_read == 0


def test_directory_rejects_path_traversal(directory):
    for bad in ("", "a/b", "..", "a\\b"):
        with pytest.raises(ValueError):
            directory.write_file(bad, b"x")


def test_rename_is_atomic_replace(directory):
    directory.write_file("dst", b"old")
    directory.write_file("src", b"new")
    directory.rename("src", "dst")
    assert directory.read_file("dst") == b"new"
    assert not directory.file_exists("src")


def test_fs_mmap_reads_identical_with_unchanged_accounting(tmp_path):
    """``FSDirectory(mmap=True)`` serves identical bytes through the
    mapping, falls back to plain reads where mmap cannot apply (empty
    files), and keeps the byte accounting identical to the plain-read
    directory — measured envelopes stay comparable across modes."""
    plain = FSDirectory(tmp_path / "a")
    mapped = FSDirectory(tmp_path / "b", mmap=True)
    payload = b"x" * 4096 + b"tail"
    for d in (plain, mapped):
        d.write_file("f", payload)
        d.write_file("empty", b"")
        assert d.read_file("f") == payload
        assert d.read_file("empty") == b""
    assert mapped.mmap_reads == 1          # "f" via the map, "empty" not
    assert plain.mmap_reads == 0
    assert mapped.bytes_read == plain.bytes_read == len(payload)
    assert mapped.bytes_written == plain.bytes_written
    with pytest.raises(FileNotFoundError):
        mapped.read_file("zz")
    # a full durable cycle through an mmap directory stays bit-identical
    seg = make_segment(np.random.default_rng(0), 0, n_docs=6)
    store = SegmentStore(directory=mapped)
    store.write(seg)
    store.commit([seg])
    gen, segs = open_latest(FSDirectory(tmp_path / "b", mmap=True))
    assert gen == 1 and len(segs) == 1
    assert_bit_identical(segs[0], seg)


# ---------------------------------------------------------------------------
# DeviceThrottle / ThrottledDirectory
# ---------------------------------------------------------------------------

def test_throttle_accounts_exact_device_time():
    prof = MediaProfile("toy", read_bw=100.0, write_bw=50.0,
                        read_latency_s=0.5, write_latency_s=1.0)
    th = DeviceThrottle(prof)  # pace=0: accounting only, no sleeping
    d = ThrottledDirectory(RAMDirectory(), th)
    d.write_file("f", b"x" * 100)        # 1.0 + 100/50 = 3.0
    d.read_file("f")                     # 0.5 + 100/100 = 1.5
    assert th.busy_write_s == pytest.approx(3.0)
    assert th.busy_read_s == pytest.approx(1.5)
    assert th.busy_s == pytest.approx(4.5)
    assert th.ops_read == 1 and th.ops_write == 1
    # bytes really landed in the inner store, and both layers measured them
    assert d.inner.read_file("f") == b"x" * 100
    assert d.bytes_written == 100 and d.inner.bytes_written == 100
    th.reset()
    assert th.busy_s == 0.0


def test_shared_throttle_serializes_two_directories():
    """Source and target on ONE throttle = one controller: its timeline
    is the sum of both streams (the paper's shared-media case)."""
    prof = MediaProfile("toy", read_bw=100.0, write_bw=100.0)
    shared = DeviceThrottle(prof)
    src = ThrottledDirectory(RAMDirectory(), shared)
    tgt = ThrottledDirectory(RAMDirectory(), shared)
    src.write_file("col", b"r" * 200)
    shared.reset()  # spooling is not part of the run
    src.read_file("col")
    tgt.write_file("idx", b"w" * 300)
    assert shared.busy_s == pytest.approx(2.0 + 3.0)
    # isolated pair: two timelines overlap, envelope is the max
    th_s, th_t = DeviceThrottle(prof), DeviceThrottle(prof)
    ThrottledDirectory(RAMDirectory(), th_s).write_file("a", b"r" * 200)
    ThrottledDirectory(RAMDirectory(), th_t).write_file("b", b"w" * 300)
    assert max(th_s.busy_s, th_t.busy_s) == pytest.approx(3.0)


def test_scaled_profile():
    p = MEDIA_PROFILES["ssd"].scaled(1000.0)
    assert p.read_bw == pytest.approx(MEDIA_PROFILES["ssd"].read_bw / 1000)
    assert p.write_bw == pytest.approx(MEDIA_PROFILES["ssd"].write_bw / 1000)


# ---------------------------------------------------------------------------
# codec: bit-identical round trip (the oracle) + corruption
# ---------------------------------------------------------------------------

def _roundtrip(seg, codec):
    return codec_mod.decode_segment(codec_mod.encode_segment(seg, codec))


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 100000), st.integers(0, 4),
       st.sampled_from(codec_mod.CODECS + (codec_mod.AUTO,)))
def test_codec_roundtrip_bit_identical(seed, kind, codec):
    """Randomized segments (empty, zero-postings, one-term,
    single-posting-term, generic) encode -> decode bit-identically."""
    rng = np.random.default_rng(seed)
    seg = make_segment(rng, base=int(rng.integers(0, 50000)),
                       n_docs=0 if kind == 0 else int(rng.integers(1, 9)),
                       max_terms=0 if kind == 3 else 12,
                       one_term=kind == 1, single_postings=kind == 2,
                       generation=int(rng.integers(0, 4)))
    assert_bit_identical(seg, _roundtrip(seg, codec))


@pytest.mark.parametrize("codec", codec_mod.CODECS + (codec_mod.AUTO,))
def test_codec_roundtrip_max_doc_id(codec):
    """Doc ids at the top of the uint32 range survive exactly (the first
    posting of a term is stored absolute, so it is the largest value any
    packed stream carries)."""
    rng = np.random.default_rng(3)
    seg = make_segment(rng, base=(1 << 32) - 12, n_docs=8)
    assert int(seg.doc_ids.max()) == (1 << 32) - 5
    assert_bit_identical(seg, _roundtrip(seg, codec))


def test_codec_rejects_doc_ids_beyond_uint32():
    rng = np.random.default_rng(4)
    seg = make_segment(rng, base=1 << 32, n_docs=4)
    with pytest.raises(ValueError, match="uint32"):
        codec_mod.encode_segment(seg, "pfor")
    # the raw codec stores int64 and has no such ceiling
    assert_bit_identical(seg, _roundtrip(seg, "raw"))
    # auto degrades stream-by-stream: when every compressed candidate
    # refuses a stream's value domain it falls back to raw, losslessly
    assert_bit_identical(seg, _roundtrip(seg, codec_mod.AUTO))


@pytest.mark.parametrize("codec", ["pfor", "adaptive", "pef", "auto"])
@pytest.mark.parametrize("suffix", SEGMENT_SUFFIXES)
@pytest.mark.parametrize("damage", ["flip", "truncate", "missing"])
def test_corrupt_segment_files_fail_cleanly(directory, codec, suffix,
                                            damage):
    """A torn or bit-flipped file raises CorruptSegment from the checksum
    layer — it must never decode to a wrong Segment. Every compressed
    codec goes through the same matrix: the frame (declared length +
    crc32) guards the payload regardless of what encoded it."""
    rng = np.random.default_rng(5)
    seg = make_segment(rng, 0, n_docs=6)
    codec_mod.write_segment(directory, "s0", seg, codec)
    name = "s0" + suffix
    data = directory.read_file(name)
    if damage == "flip":
        buf = bytearray(data)
        buf[len(buf) // 2] ^= 0x40
        directory.write_file(name, bytes(buf))
    elif damage == "truncate":
        directory.write_file(name, data[:max(len(data) // 2, 1)])
    else:
        directory.delete_file(name)
    with pytest.raises(CorruptSegment):
        codec_mod.read_segment(directory, "s0")


def test_codec_compresses_vs_raw():
    """On a realistically sized segment every compressed codec beats the
    raw int64 stream (the reason the codecs exist: fewer bytes cross the
    device)."""
    rng = np.random.default_rng(6)
    seg = make_segment(rng, 0, n_docs=64, vocab=400, max_terms=200,
                       max_tf=4)
    sizes = {c: sum(len(b) for b in
                    codec_mod.encode_segment(seg, c).values())
             for c in codec_mod.CODECS}
    for c in ("pfor", "adaptive", "pef"):
        assert sizes[c] < sizes["raw"], (c, sizes)


def _pattern_stream(rng, pattern):
    """The value-pattern matrix for stream-level codec oracles: empty,
    single value, dense (tiny gaps), sparse (large gaps), and values at
    the top of the uint32 range."""
    if pattern == "empty":
        return np.zeros(0, np.int64)
    if pattern == "single":
        return rng.integers(0, 1 << 32, 1).astype(np.int64)
    if pattern == "dense":
        return rng.integers(0, 3, 400).astype(np.int64)
    if pattern == "sparse":
        return rng.integers(0, 1 << 20, 200).astype(np.int64)
    return np.concatenate([[(1 << 32) - 1] * 4,
                           rng.integers(0, 1 << 32, 8)]).astype(np.int64)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 10 ** 6),
       st.sampled_from(codec_mod.CODECS + (codec_mod.AUTO,)),
       st.sampled_from(["empty", "single", "dense", "sparse", "max"]))
def test_stream_codecs_roundtrip_and_match_naive_oracle(seed, codec,
                                                        pattern):
    """Stream-level bit-identity across ALL codecs x value patterns: the
    vectorized decoder and the scalar naive oracle must both reproduce
    the input exactly AND agree on how many bytes the stream occupies
    (trailing bytes stay untouched — streams are concatenated in every
    segment file, so a length disagreement corrupts the next stream)."""
    rng = np.random.default_rng(seed)
    arr = _pattern_stream(rng, pattern)
    buf = codec_mod._enc_stream(arr, codec) + b"tail!"
    got, off = codec_mod._dec_stream(buf, 0)
    naive, off_n = codec_mod.decode_stream_naive(buf, 0)
    assert off == off_n == len(buf) - 5
    assert got.dtype == naive.dtype == np.int64
    assert np.array_equal(got, arr)
    assert np.array_equal(naive, arr)


def test_codec_auto_picks_smallest_codec_per_stream():
    """codec="auto": every stream carries whichever compressed codec
    came out smallest FOR ITS VALUES, recorded in the stream's leading
    id byte (``stream_codec_name``), and decodes bit-identically."""
    rng = np.random.default_rng(7)
    for pattern in ("empty", "single", "dense", "sparse", "max"):
        arr = _pattern_stream(rng, pattern)
        buf = codec_mod._enc_stream(arr, codec_mod.AUTO)
        sizes = {}
        for c in codec_mod._AUTO_CANDIDATES:
            try:
                sizes[c] = len(codec_mod._enc_stream(arr, c))
            except ValueError:
                pass
        assert sizes and len(buf) == min(sizes.values()), pattern
        chosen = codec_mod.stream_codec_name(buf)
        assert sizes[chosen] == len(buf)
        got, off = codec_mod._dec_stream(buf, 0)
        assert off == len(buf) and np.array_equal(got, arr)
    # a stream no compressed candidate can hold falls back to raw
    # (values past uint32 refuse pfor/adaptive; prefix sums past the
    # int64 headroom refuse pef)
    big = np.array([1 << 61, 1 << 61], np.int64)
    buf = codec_mod._enc_stream(big, codec_mod.AUTO)
    assert codec_mod.stream_codec_name(buf) == "raw"
    assert np.array_equal(codec_mod._dec_stream(buf, 0)[0], big)


def test_codec_auto_never_larger_than_any_single_codec():
    """The whole-segment consequence of per-stream argmin: an auto
    segment is at most as large as the best single compressed codec."""
    rng = np.random.default_rng(8)
    seg = make_segment(rng, 0, n_docs=64, vocab=400, max_terms=200,
                       max_tf=4)
    sizes = {c: sum(len(b) for b in
                    codec_mod.encode_segment(seg, c).values())
             for c in ("pfor", "adaptive", "pef", codec_mod.AUTO)}
    assert sizes[codec_mod.AUTO] <= min(
        sizes[c] for c in ("pfor", "adaptive", "pef")), sizes
    assert_bit_identical(seg, _roundtrip(seg, codec_mod.AUTO))


@pytest.mark.parametrize("codec", codec_mod.CODECS + (codec_mod.AUTO,))
def test_reorder_permutation_roundtrips_and_validates(codec):
    """The BP doc-id permutation rides the ``.doc`` file: it must survive
    encode -> decode bit-identically under every codec, absent stays
    absent, and a corrupted flag or non-permutation payload fails as
    CorruptSegment instead of decoding to a broken block layout."""
    rng = np.random.default_rng(30)
    seg = make_segment(rng, 0, n_docs=8)
    assert _roundtrip(seg, codec).reorder is None
    from dataclasses import replace
    perm = rng.permutation(seg.n_docs).astype(np.int64)
    reordered = replace(seg, reorder=perm)
    got = _roundtrip(reordered, codec)
    assert got.reorder is not None and got.reorder.dtype == np.int64
    assert np.array_equal(got.reorder, perm)
    assert_bit_identical(seg, got)  # logical arrays stay natural-order
    # a reorder that is not a permutation of the doc slots must not load
    bad = replace(seg, reorder=np.zeros(seg.n_docs, np.int64))
    files = codec_mod.encode_segment(bad, codec)
    with pytest.raises(CorruptSegment, match="permutation"):
        codec_mod.decode_segment(files)
    # an invalid flag byte fails loudly too
    files = codec_mod.encode_segment(seg, codec)
    payload = codec_mod.unframe(files[".doc"], codec_mod.KIND_DOC)
    files[".doc"] = codec_mod.frame(codec_mod.KIND_DOC,
                                    payload[:-1] + b"\x07")
    with pytest.raises(CorruptSegment, match="flag"):
        codec_mod.decode_segment(files)


def test_reorder_survives_liv_and_store_roundtrip(directory):
    """Durable lifecycle with a reordered segment: commit, roll a delete
    generation, recover — the permutation and the tombstones both come
    back (readers rebuilt from a recovered index keep the clustered
    block layout)."""
    from dataclasses import replace
    rng = np.random.default_rng(31)
    base = make_segment(rng, 0, n_docs=8)
    seg = replace(base, reorder=rng.permutation(8).astype(np.int64))
    store, _ = SegmentStore.open(directory)
    store.write(seg)
    store.commit([seg])
    d1 = seg.with_deletes(seg.doc_ids[:2])
    assert np.array_equal(d1.reorder, seg.reorder)  # deletes keep BP
    store.relabel(seg, d1)
    store.commit([d1])
    _, segs = open_latest(directory)
    assert len(segs) == 1 and segs[0].n_deleted == 2
    assert np.array_equal(segs[0].reorder, seg.reorder)
    assert_bit_identical(base, replace(segs[0], reorder=None))


# ---------------------------------------------------------------------------
# commit points + recovery
# ---------------------------------------------------------------------------

def test_open_latest_empty(directory):
    assert open_latest(directory) == (0, [])


def test_commit_is_two_phase_and_supersedes(directory):
    rng = np.random.default_rng(7)
    store, segs = SegmentStore.open(directory)
    assert (store.gen, segs) == (0, [])
    a, b = make_segment(rng, 0, n_docs=4), make_segment(rng, 100, n_docs=4)
    store.write(a)
    store.write(b)
    gen = store.commit([a, b])
    assert gen == 1 and list_commits(directory) == [1]
    assert not directory.file_exists(manifest_name(1) + ".tmp")
    meta = read_commit(directory, manifest_name(1))
    assert len(meta["segments"]) == 2 and meta["codec"] == "pfor"
    # supersede: merge installs -> inputs marked -> next commit deletes
    from repro.core.merge import merge_segments
    m = merge_segments([a, b])
    store.write(m)
    store.mark_superseded([a, b])
    assert store.commit([m]) == 2
    live_files = [f for f in directory.list_files()
                  if not f.startswith("segments")]
    assert len(live_files) == len(SEGMENT_SUFFIXES)  # only m remains
    assert list_commits(directory) == [2]  # old manifest deleted too
    gen2, segs2 = open_latest(directory)
    assert gen2 == 2 and len(segs2) == 1
    assert_bit_identical(m, segs2[0])


def test_commit_never_deletes_inflight_merge_output(directory):
    """Regression: a merge output that has been written but not yet
    installed is not superseded and not in the commit's live snapshot —
    a racing commit must leave its files alone (previously they were
    deleted as 'dead' and the next commit raised ValueError)."""
    from repro.core.merge import merge_segments
    rng = np.random.default_rng(12)
    store, _ = SegmentStore.open(directory)
    a, b = make_segment(rng, 0, n_docs=4), make_segment(rng, 100, n_docs=4)
    store.write(a)
    store.write(b)
    store.commit([a, b])
    m = merge_segments([a, b])
    store.write(m)                    # worker: output written...
    gen = store.commit([a, b])        # ...ingest commits pre-install
    m_name = store._names[m.seg_id]
    for sfx in SEGMENT_SUFFIXES:
        assert directory.file_exists(m_name + sfx), \
            "in-flight merge output deleted by a racing commit"
    store.mark_superseded([a, b])     # worker: install completes
    gen2 = store.commit([m])          # next commit publishes the output
    assert gen2 == gen + 1
    latest, segs = open_latest(directory)
    assert latest == gen2 and len(segs) == 1
    assert_bit_identical(m, segs[0])
    # and the superseded inputs' files are gone now
    live = {m_name + sfx for sfx in SEGMENT_SUFFIXES}
    assert {f for f in directory.list_files()
            if not f.startswith("segments")} == live


def test_concurrent_merges_with_interleaved_commits(tmp_path):
    """Background merge workers write outputs while the ingest thread
    commits: no commit may lose a segment, and the final recovery holds
    every doc exactly once."""
    cfg = SMOKE_CFG
    corpus = SyntheticCorpus(TINY, doc_buffer_len=cfg.doc_len)
    ix = DistributedIndexer(cfg=cfg, target_dir=FSDirectory(tmp_path / "i"),
                            merge_threads=2)
    try:
        for i in range(12):
            ix.index_batch(corpus.batch(i, 16))
            if i % 3 == 2:
                ix.commit()
        final = ix.finalize()
    finally:
        ix.close()
    assert final.n_docs == 192
    gen, searcher = open_searcher(FSDirectory(tmp_path / "i"))
    assert searcher.n_docs == 192
    _, segs = open_latest(FSDirectory(tmp_path / "i"))
    all_ids = np.sort(np.concatenate([s.doc_ids for s in segs]))
    assert (all_ids == np.arange(192)).all()


def test_resume_keeps_amplification_sane(tmp_path):
    """Regression: recovered segments are credited as prior writes, so a
    resumed run's measured alpha stays >= 1 instead of collapsing (the
    old behavior divided new-run-only writes by the whole live index)."""
    cfg = SMOKE_CFG
    corpus = SyntheticCorpus(TINY, doc_buffer_len=cfg.doc_len)
    ix = DistributedIndexer(cfg=cfg, target_dir=FSDirectory(tmp_path / "i"))
    for i in range(4):
        ix.index_batch(corpus.batch(i, 16))
    ix.finalize()
    ix2 = DistributedIndexer(cfg=cfg,
                             target_dir=FSDirectory(tmp_path / "i"))
    for i in range(4, 8):
        ix2.index_batch(corpus.batch(i, 16))
    ix2.finalize()
    rep = ix2.envelope_report()
    assert rep["alpha_measured"] >= 1.0, rep["alpha_measured"]


def test_commit_refuses_unwritten_segment(directory):
    rng = np.random.default_rng(8)
    store, _ = SegmentStore.open(directory)
    with pytest.raises(ValueError, match="never"):
        store.commit([make_segment(rng, 0, n_docs=3)])


def test_recovery_ignores_torn_and_uncommitted_files(directory):
    """open_latest walks commits newest-first and skips any commit whose
    manifest or referenced segments fail validation; stray uncommitted
    segments and stranded tmp manifests are invisible."""
    rng = np.random.default_rng(9)
    segs1 = [make_segment(rng, 100 * i, n_docs=3) for i in range(2)]
    names1 = [f"s{i:08x}" for i in range(2)]
    for n, s in zip(names1, segs1):
        codec_mod.write_segment(directory, n, s)
    write_commit(directory, 1, names1)
    # commit 2 references a segment we then tear mid-file
    seg2 = make_segment(rng, 500, n_docs=3)
    codec_mod.write_segment(directory, "s00000002", seg2)
    write_commit(directory, 2, names1 + ["s00000002"])
    data = directory.read_file("s00000002.pst")
    directory.write_file("s00000002.pst", data[:len(data) // 2])
    # plus: a manifest that is pure garbage, a stranded tmp, a torn flush,
    # and a file the store does NOT own (a co-located source spool)
    directory.write_file("segments_9", b"not a manifest at all")
    directory.write_file("segments_7.tmp", b"\x00" * 8)
    directory.write_file("s00000009.dict", b"RSEGtorn")
    directory.write_file("batch_000000", b"spooled source data")
    gen, segs = open_latest(directory)
    assert gen == 1, "fell back past the torn commit and the garbage one"
    got = np.sort(np.concatenate([s.doc_ids for s in segs]))
    want = np.sort(np.concatenate([s.doc_ids for s in segs1]))
    assert (got == want).all()
    # SegmentStore.open cleans every unreferenced file IT could have
    # written — and nothing else (unrelated files must survive recovery)
    store, rec = SegmentStore.open(directory)
    assert store.gen == 1 and len(rec) == 2
    leftovers = set(directory.list_files())
    assert leftovers == {manifest_name(1), "batch_000000"} | {
        n + sfx for n in names1 for sfx in SEGMENT_SUFFIXES}


def test_interrupted_indexing_recovers_to_last_commit(tmp_path):
    """Kill-9 oracle: index, commit, index more WITHOUT committing, tear a
    post-commit flush, abandon the process state. A fresh indexer over the
    same path resumes at the commit point: every committed doc searchable
    exactly once, doc-id allocation continuing where the commit left off."""
    cfg = SMOKE_CFG
    corpus = SyntheticCorpus(TINY, doc_buffer_len=cfg.doc_len)
    path = tmp_path / "idx"
    ix = DistributedIndexer(cfg=cfg, target_dir=FSDirectory(path))
    for i in range(4):
        ix.index_batch(corpus.batch(i, 16))
    gen = ix.commit()
    committed = {f for f in FSDirectory(path).list_files()}
    for i in range(4, 6):  # indexed + flushed, never committed
        ix.index_batch(corpus.batch(i, 16))
    # "kill -9": no close/finalize; additionally tear one post-commit file
    d = FSDirectory(path)
    stray = sorted(set(d.list_files()) - committed)
    assert stray, "uncommitted flushes must have hit the directory"
    torn = next(f for f in stray if f.endswith(".pst"))
    d.write_file(torn, d.read_file(torn)[:10])

    gen2, searcher = open_searcher(FSDirectory(path))
    assert gen2 == gen
    assert searcher.n_docs == 64  # 4 committed batches x 16
    _, segs = open_latest(FSDirectory(path))
    all_ids = np.concatenate([s.doc_ids for s in segs])
    assert (np.sort(all_ids) == np.arange(64)).all(), \
        "every committed doc exactly once"

    # restart the indexing run from the last commit
    ix2 = DistributedIndexer(cfg=cfg, target_dir=FSDirectory(path))
    assert ix2._next_doc == 64
    assert ix2.refresh(flush=False).n_docs == 64
    for i in range(4, 8):  # re-index the lost batches and carry on
        ix2.index_batch(corpus.batch(i, 16))
    final = ix2.finalize()
    assert final.n_docs == 128
    assert (np.sort(final.doc_ids) == np.arange(128)).all()
    gen3, s3 = open_searcher(FSDirectory(path))
    assert gen3 > gen and s3.n_docs == 128


def test_durable_path_matches_in_memory_pipeline(tmp_path):
    """Writing through storage must not perturb the pipeline: the durable
    run's force-merged end state is bit-identical to the in-memory run,
    and the last commit holds exactly those bytes."""
    cfg = SMOKE_CFG
    corpus = SyntheticCorpus(TINY, doc_buffer_len=cfg.doc_len)
    mem = DistributedIndexer(cfg=cfg)
    dur = DistributedIndexer(cfg=cfg,
                             target_dir=FSDirectory(tmp_path / "idx"))
    for i in range(6):
        mem.index_batch(corpus.batch(i, 16))
        dur.index_batch(corpus.batch(i, 16))
    f_mem, f_dur = mem.finalize(), dur.finalize()
    for f in ARRAY_FIELDS:
        assert (getattr(f_mem, f) == getattr(f_dur, f)).all(), f
    assert dur.merger.n_merges == mem.merger.n_merges
    assert dur.store.bytes_encoded_read > 0  # merges re-read their inputs
    _, segs = open_latest(FSDirectory(tmp_path / "idx"))
    assert len(segs) == 1
    assert_bit_identical(
        segs[0], type(segs[0])(**{f: getattr(f_dur, f)
                                  for f in ARRAY_FIELDS},
                               generation=f_dur.generation))


def test_envelope_report_raw_and_encoded_bytes(tmp_path):
    cfg = SMOKE_CFG
    corpus = SyntheticCorpus(TINY, doc_buffer_len=cfg.doc_len)
    ix = DistributedIndexer(cfg=cfg, target_dir=FSDirectory(tmp_path / "i"))
    for i in range(3):
        ix.index_batch(corpus.batch(i, 16))
    ix.finalize()
    rep = ix.envelope_report()
    live = ix.merger.live_segments()
    # one authoritative source for each figure
    assert rep["index_bytes_raw"] == sum(s.total_bytes() for s in live)
    assert rep["index_bytes_encoded"] == \
        ix.store.encoded_bytes_live(live) > 0
    assert rep["bytes_written_measured"] == ix.target_dir.bytes_written
    # without storage the encoded figure is explicitly zero, raw persists
    mem = DistributedIndexer(cfg=cfg)
    mem.index_batch(corpus.batch(0, 16))
    mem.finalize()
    rep2 = mem.envelope_report()
    assert rep2["index_bytes_encoded"] == 0 and rep2["index_bytes_raw"] > 0


# ---------------------------------------------------------------------------
# spooled source collection
# ---------------------------------------------------------------------------

def test_spool_roundtrip_and_checksum(directory):
    corpus = SyntheticCorpus(TINY, doc_buffer_len=48)
    total = spool_corpus(corpus, directory, 3, 8)
    assert total == directory.bytes_written
    got = list(iter_spooled(directory))
    assert [i for i, _ in got] == [0, 1, 2]
    for i, toks in got:
        assert toks.dtype == np.int32
        assert (toks == corpus.batch(i, 8)).all()
    data = directory.read_file("batch_000001")
    directory.write_file("batch_000001", data[:-3])
    with pytest.raises(CorruptSegment):
        list(iter_spooled(directory))


def test_measured_isolation_beats_shared_media(tmp_path):
    """The paper's headline result, measured in silico: the same corpus
    indexed NAS->SSD (two device timelines, streams overlap) yields a
    higher measured GB/min than SSD->SSD (one timeline serves both)."""
    cfg = SMOKE_CFG
    corpus = SyntheticCorpus(TINY, doc_buffer_len=cfg.doc_len)

    def run(src_profile, shared):
        th_t = DeviceThrottle(MEDIA_PROFILES["ssd"])
        th_s = th_t if shared else DeviceThrottle(MEDIA_PROFILES[src_profile])
        src = ThrottledDirectory(RAMDirectory(), th_s)
        tgt = ThrottledDirectory(RAMDirectory(), th_t)
        spool_corpus(corpus, src, 4, 16)
        src.reset_counters()
        th_s.reset()
        ix = DistributedIndexer(cfg=cfg, source="ceph", target="ssd",
                                source_dir=src, target_dir=tgt)
        assert ix.index_spooled() == 64
        ix.finalize()
        return ix.envelope_report()

    iso = run("nas", shared=False)
    sh = run("ssd", shared=True)
    assert sh["shared_media_measured"] and not iso["shared_media_measured"]
    assert iso["gb_per_min_measured"] > sh["gb_per_min_measured"]
    assert iso["bytes_read_measured"] == sh["bytes_read_measured"] > 0


# ---------------------------------------------------------------------------
# document lifecycle: .liv delete generations + sync barrier
# ---------------------------------------------------------------------------

def test_liveness_roundtrip_and_validation():
    rng = np.random.default_rng(20)
    for n in (0, 1, 7, 8, 9, 200):
        mask = rng.random(n) < 0.3
        data = codec_mod.encode_liveness(mask)
        got = codec_mod.decode_liveness(data, n)
        assert got.dtype == bool and (got == mask).all()
    data = codec_mod.encode_liveness(np.array([True, False, True]))
    with pytest.raises(CorruptSegment, match="covers"):
        codec_mod.decode_liveness(data, 4)   # wrong segment
    buf = bytearray(data)
    buf[len(buf) // 2] ^= 0x10
    with pytest.raises(CorruptSegment):
        codec_mod.decode_liveness(bytes(buf), 3)
    with pytest.raises(CorruptSegment):
        codec_mod.decode_liveness(data[:-6], 3)


def test_directory_sync_barrier(directory):
    directory.write_file("a", b"xx")
    directory.write_file("b", b"yyy")
    directory.sync(["a", "b"])           # no-op on RAM, fsync batch on FS
    assert directory.syncs == 2
    assert directory.sync_wall_s >= 0.0
    with pytest.raises(FileNotFoundError):
        directory.sync(["nope"])
    with pytest.raises(ValueError):
        directory.sync(["a/b"])


def test_throttled_sync_charges_latency_only():
    prof = MediaProfile("toy", read_bw=100.0, write_bw=100.0,
                        write_latency_s=0.25)
    th = DeviceThrottle(prof)
    d = ThrottledDirectory(RAMDirectory(), th)
    d.write_file("f", b"x" * 100)
    before = th.busy_write_s
    d.sync(["f"])
    # one write-latency round trip, no bandwidth term
    assert th.busy_write_s == pytest.approx(before + 0.25)
    assert d.syncs == 1 and d.inner.syncs == 1


def test_commit_writes_and_supersedes_liv_generations(directory):
    """A growing bitmap rolls .liv generations forward WITHOUT rewriting
    the segment; each commit references exactly one generation and
    deletes the stale one; recovery re-attaches the committed bitmap."""
    rng = np.random.default_rng(21)
    store, _ = SegmentStore.open(directory)
    seg = make_segment(rng, 0, n_docs=8)
    store.write(seg)
    store.commit([seg])
    core_files = {f for f in directory.list_files()
                  if not f.startswith("segments")}

    d1 = seg.with_deletes(seg.doc_ids[:2])
    store.relabel(seg, d1)
    store.commit([d1])
    livs = [f for f in directory.list_files() if f.endswith(".liv")]
    assert livs == [f"{store._names[seg.seg_id]}_1.liv"]
    assert {f for f in directory.list_files()
            if not f.startswith("segments")} == core_files | set(livs)

    d2 = d1.with_deletes(seg.doc_ids[4:5])
    store.relabel(d1, d2)
    store.commit([d2])
    livs = [f for f in directory.list_files() if f.endswith(".liv")]
    assert livs == [f"{store._names[seg.seg_id]}_2.liv"]  # gen 1 deleted

    # an UNCHANGED bitmap does not roll a new generation
    store.commit([d2])
    assert [f for f in directory.list_files()
            if f.endswith(".liv")] == livs

    gen, segs = open_latest(directory)
    assert len(segs) == 1 and segs[0].n_deleted == 3
    assert (segs[0].live_doc_ids() == seg.doc_ids[[2, 3, 5, 6, 7]]).all()
    # the recovered store registers the liv generation and keeps rolling
    store2, rec = SegmentStore.open(directory)
    assert rec[0].n_deleted == 3
    d3 = rec[0].with_deletes(seg.doc_ids[6:7])
    store2.relabel(rec[0], d3)
    store2.commit([d3])
    livs = [f for f in directory.list_files() if f.endswith(".liv")]
    assert livs == [f"{store2._names[rec[0].seg_id]}_3.liv"]


def test_kill9_between_liv_write_and_commit_recovers_previous(directory):
    """The torn-commit matrix extended to delete generations: a crash
    after writing a newer .liv (or a manifest referencing a torn/missing
    one) must recover the PREVIOUS delete generation, every committed doc
    searchable exactly once."""
    rng = np.random.default_rng(22)
    store, _ = SegmentStore.open(directory)
    seg = make_segment(rng, 0, n_docs=8)
    store.write(seg)
    d1 = seg.with_deletes(seg.doc_ids[:2])
    store.relabel(seg, d1)
    gen1 = store.commit([d1])
    base = store._names[seg.seg_id]

    # crash flavor 1: newer .liv written, manifest never appeared
    directory.write_file(f"{base}_2.liv",
                         codec_mod.encode_liveness(
                             np.isin(seg.doc_ids, seg.doc_ids[:5])))
    gen, segs = open_latest(directory)
    assert gen == gen1 and segs[0].n_deleted == 2  # previous generation
    assert (np.sort(segs[0].doc_ids) == seg.doc_ids).all()

    # crash flavor 2: manifest references a TORN .liv
    data = directory.read_file(f"{base}_2.liv")
    directory.write_file(f"{base}_2.liv", data[:len(data) // 2])
    write_commit(directory, gen1 + 1, [base],
                 liv={base: f"{base}_2.liv"})
    gen, segs = open_latest(directory)
    assert gen == gen1 and segs[0].n_deleted == 2

    # crash flavor 3: manifest landed but its .liv evaporated (lost write)
    directory.write_file(f"{base}_2.liv", data)   # valid again, briefly
    write_commit(directory, gen1 + 2, [base],
                 liv={base: f"{base}_2.liv"})
    directory.delete_file(f"{base}_2.liv")
    gen, segs = open_latest(directory)
    assert gen == gen1 and segs[0].n_deleted == 2
    live = segs[0].live_doc_ids()
    assert live.size == 6 and np.unique(live).size == 6  # exactly once

    # recovery cleanup drops the orphan manifests; committed state intact
    store2, rec = SegmentStore.open(directory)
    assert store2.gen == gen1 and rec[0].n_deleted == 2
    assert list_commits(directory) == [gen1]


def test_kill9_mid_lifecycle_full_stack(tmp_path):
    """Index + delete + commit, then more deletes + a .liv written but
    torn before its manifest: a fresh indexer recovers the committed
    lifecycle state (deletes included) and resumes doc-id allocation."""
    cfg = SMOKE_CFG
    corpus = SyntheticCorpus(TINY, doc_buffer_len=cfg.doc_len)
    path = tmp_path / "idx"
    ix = DistributedIndexer(cfg=cfg, target_dir=FSDirectory(path))
    for i in range(3):
        ix.index_batch(corpus.batch(i, 16))
    ix.delete([1, 2, 3])
    gen = ix.commit()
    committed = set(FSDirectory(path).list_files())

    ix.delete([10, 11])                   # acked, never committed
    ix.refresh()
    # "kill -9" before the next commit, with the newer .liv torn on disk
    d = FSDirectory(path)
    for f in sorted(set(d.list_files()) - committed):
        d.write_file(f, d.read_file(f)[:8])

    gen2, searcher = open_searcher(FSDirectory(path))
    assert gen2 == gen
    assert searcher.n_docs == 45          # 48 committed docs - 3 deletes
    q = np.unique(corpus.batch(0, 16))[1:4].astype(np.int32)
    _, ids = searcher.search(q, 45)
    ids = np.asarray(ids)
    assert not np.isin(ids[ids >= 0], [1, 2, 3]).any()
    # the torn (never-committed) deletes of 10/11 must NOT have applied:
    # k covers every live doc, so both must come back
    assert np.isin(ids[ids >= 0], [10, 11]).sum() == 2

    ix2 = DistributedIndexer(cfg=cfg, target_dir=FSDirectory(path))
    assert ix2._next_doc == 48
    assert ix2.refresh(flush=False).n_docs == 45
    ix2.delete([10, 11])                  # re-issue the lost deletes
    final = ix2.finalize()
    assert final.n_docs == 43 and not final.has_deletes
    assert not np.isin([1, 2, 3, 10, 11], final.doc_ids).any()


def test_calibrate_accepts_measured_runs():
    """calibrate(measured=...) folds this repo's own ThrottledDirectory
    measurements into the fit next to the paper's Table 1."""
    from repro.core import envelope as env
    base_media, base_p, _ = env.calibrate()
    run = env.MeasuredRun(source="nas", target="ssd", raw_gb=231.0,
                          index_gb=685.0, seconds=4000.0)
    assert run.media_names() == ("ceph", "ssd")
    media, p, table = env.calibrate(measured=[run], measured_weight=2.0)
    assert p.alpha != base_p.alpha  # the measured point moved the fit
    assert 1.5 <= p.alpha <= 4.0   # but stayed inside physical bounds
    errs = [abs(v["err"]) for v in table.values()]
    assert float(np.mean(errs)) < 0.2  # Table 1 still well fit


# ---------------------------------------------------------------------------
# CachingDirectory: the hot-term postings cache (ISSUE 9)
# ---------------------------------------------------------------------------

def _framed(name, payload):
    from repro.storage.scrub import expected_kind
    return codec_mod.frame(expected_kind(name), payload)


def test_caching_directory_hits_misses_and_invalidation():
    ram = RAMDirectory()
    for name in ("s0.pst", "s0.dict", "s0_d1.doc", "s01.pst"):
        ram.write_file(name, _framed(name, b"x" * 100))
    cd = CachingDirectory(ram, cap_bytes=1 << 20)
    a = cd.read_file("s0.pst")
    assert cd.cache_misses == 1 and cd.cache_hits == 0
    before = ram.bytes_read
    assert cd.read_file("s0.pst") == a        # hit: inner never touched
    assert cd.cache_hits == 1 and ram.bytes_read == before
    assert cd.cache_bytes > 0
    # non-postings names pass through uncached
    ram.write_file("segments_1", b"manifest")
    cd.read_file("segments_1")
    cd.read_file("segments_1")
    assert cd.cache_misses == 1               # unchanged
    # mutation through the cache drops the entry
    cd.read_file("s0.dict")
    cd.write_file("s0.dict", _framed("s0.dict", b"y" * 50))
    assert cd.read_file("s0.dict") == _framed("s0.dict", b"y" * 50)
    assert cd.cache_misses == 3               # re-read after the write
    # invalidate_base drops the family (base.* and base_dN.*) only
    cd.read_file("s0_d1.doc")
    cd.read_file("s01.pst")
    assert cd.invalidate_base("s0") == 3      # s0.pst s0.dict s0_d1.doc
    h = cd.cache_hits
    cd.read_file("s01.pst")                   # the neighbour survived
    assert cd.cache_hits == h + 1
    cd.read_file("s0.pst")                    # the family did not
    assert cd.cache_misses == 6
    # rename and delete invalidate too
    cd.rename("s01.pst", "s02.pst")
    cd.read_file("s02.pst")
    assert cd.cache_misses == 7
    cd.delete_file("s02.pst")
    assert not cd.file_exists("s02.pst")


def test_caching_directory_lfu_eviction_and_crc_gate():
    ram = RAMDirectory()
    for n in ("a.pst", "b.pst", "c.pst"):
        ram.write_file(n, _framed(n, b"x" * 100))
    size = ram.file_size("a.pst")
    cd = CachingDirectory(ram, cap_bytes=2 * size)
    cd.read_file("a.pst")
    cd.read_file("a.pst")                     # freq 2: pinned
    cd.read_file("b.pst")                     # freq 1
    cd.read_file("c.pst")                     # over cap: evicts b (LFU)
    assert cd.cache_evictions == 1 and cd.cache_bytes == 2 * size
    h, m = cd.cache_hits, cd.cache_misses
    cd.read_file("a.pst")
    assert cd.cache_hits == h + 1             # the hot block stayed
    cd.read_file("b.pst")
    assert cd.cache_misses == m + 1           # the evicted one re-reads
    # a block that fails its frame crc is served through, never retained
    rot = bytearray(_framed("rot.doc", b"z" * 40))
    rot[-1] ^= 0x01
    ram.write_file("rot.doc", bytes(rot))
    assert cd.read_file("rot.doc") == bytes(rot)
    assert cd.read_file("rot.doc") == bytes(rot)
    assert cd.cache_rejected == 2             # both reads refused to fill
    # blocks larger than the whole cap are never cached either
    ram.write_file("big.pst", _framed("big.pst", b"y" * (4 * size)))
    cd.read_file("big.pst")
    assert cd.cache_rejected == 3


def test_indexer_postings_cache_wraps_target_and_reports():
    """cfg.postings_cache_mb > 0 wraps the indexer's target directory:
    segment-(re)open traffic hits the cache instead of media, counters
    surface in envelope_report, and the scrubber still reads BELOW the
    cache so cached blocks cannot mask on-media rot."""
    cfg = dataclasses.replace(SMOKE_CFG, postings_cache_mb=4.0)
    ram = RAMDirectory()
    ix = DistributedIndexer(cfg=cfg, target_dir=ram)
    assert isinstance(ix.target_dir, CachingDirectory)
    assert ix.target_dir.inner is ram
    rng = np.random.default_rng(9)
    ix.index_batch(rng.integers(1, 4096, (16, 64)).astype(np.int32))
    ix.commit()
    open_latest(ix.target_dir)                # cold reopen: fills
    assert ix.target_dir.cache_misses > 0
    cold = ram.bytes_read
    h0 = ix.target_dir.cache_hits
    gen, segs = open_latest(ix.target_dir)    # warm reopen: served from RAM
    assert gen == 1 and len(segs) == 1
    assert ix.target_dir.cache_hits > h0
    assert ram.bytes_read - cold < cold       # only uncached names re-read
    rep = ix.envelope_report()
    for key in ("postings_cache_hits", "postings_cache_misses",
                "postings_cache_evictions", "postings_cache_bytes"):
        assert key in rep
    assert rep["postings_cache_hits"] == ix.target_dir.cache_hits
    ix.close()
