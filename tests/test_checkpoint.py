"""Fault tolerance: atomic checkpoints, crash recovery, keep-k GC, async
writer, bitwise-reproducible restart of the data pipeline."""
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data.lm import LMBatches
from repro.optim import adamw


def make_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
            "scalar": jnp.float32(3.5)}


def test_roundtrip(tmp_path):
    tree = make_tree()
    ckpt.save(tmp_path, 7, tree)
    restored, step = ckpt.restore(tmp_path, tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_crash_leaves_no_corrupt_checkpoint(tmp_path):
    tree = make_tree()
    ckpt.save(tmp_path, 1, tree)
    # simulate a crash mid-write: a stale .tmp directory with garbage
    tmp = tmp_path / "step_000000002.tmp"
    tmp.mkdir()
    (tmp / "arrays.npz").write_bytes(b"garbage")
    assert ckpt.latest_step(tmp_path) == 1  # .tmp is not visible
    restored, step = ckpt.restore(tmp_path, tree)
    assert step == 1


def test_keep_k_gc(tmp_path):
    tree = make_tree()
    for s in range(6):
        ckpt.save(tmp_path, s, tree, keep_k=3)
    steps = sorted(p.name for p in tmp_path.iterdir())
    assert len(steps) == 3 and steps[-1] == "step_000000005"


def test_async_checkpointer(tmp_path):
    tree = make_tree()
    acp = ckpt.AsyncCheckpointer(tmp_path)
    for s in range(3):
        acp.save_async(s, jax.tree.map(lambda x: x + s, tree))
    acp.wait()
    restored, step = ckpt.restore(tmp_path, tree)
    assert step == 2
    np.testing.assert_allclose(np.asarray(restored["scalar"]), 5.5)


def test_training_resume_is_bitwise(tmp_path):
    """Kill-and-restart: resumed run reproduces the uninterrupted run."""
    data = LMBatches(vocab_size=64, batch=4, seq_len=8, seed=42)
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (64, 64)) * 0.1}

    def loss_fn(p, batch):
        x = p["w"][batch["tokens"].reshape(-1)]
        logits = x @ p["w"].T
        t = batch["targets"].reshape(-1)
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(len(t)), t])

    @jax.jit
    def step_fn(p, opt, batch):
        loss, g = jax.value_and_grad(loss_fn)(p, batch)
        p2, opt2, _ = adamw.update(p, g, opt, lr=1e-2)
        return p2, opt2, loss

    def run(p, opt, start, end, ckdir=None):
        for s in range(start, end):
            b = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
            p, opt, loss = step_fn(p, opt, b)
            if ckdir is not None:
                ckpt.save(ckdir, s, {"params": p, "opt": opt})
        return p, opt

    opt0 = adamw.init(params)
    # uninterrupted
    pA, _ = run(params, opt0, 0, 8)
    # interrupted at 5, restart from checkpoint
    run(params, opt0, 0, 5, ckdir=tmp_path)
    state, last = ckpt.restore(tmp_path, {"params": params, "opt": opt0})
    assert last == 4
    pB, _ = run(state["params"], state["opt"], 5, 8)
    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_restore_reshard(tmp_path):
    """Restore onto a different device topology (elastic scaling): arrays
    are stored unsharded and re-placed with the new sharding."""
    tree = make_tree()
    ckpt.save(tmp_path, 3, tree)
    shardings = jax.tree.map(
        lambda x: jax.sharding.SingleDeviceSharding(jax.devices()[0]), tree)
    restored, _ = ckpt.restore(tmp_path, tree, shardings=shardings)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
