"""Fault-injection harness + hardened IO path (ISSUE 7).

The acceptance invariants:
  * every injected fault kind (transient/persistent EIO, ENOSPC, torn
    write, silent bit flip, latency spike) is reproducible by seed or
    script, and counted;
  * capped-backoff retries heal any transient fault whose consecutive-
    failure run fits the cap, never retry ENOSPC / missing files, and
    surface the typed ``RetriesExhausted`` past the cap — retries are
    BOUNDED by the policy, by construction;
  * the WAL makes acked-but-unflushed ingest durable: replay restores
    every acked op in order, skips torn (never-acked) tails, and
    truncates at commit;
  * a commit with one corrupt segment serves the rest (quarantine +
    degraded serving), the loss is sized honestly, and a still-live
    quarantined segment self-heals at the next commit;
  * the checksum scrubber finds post-commit bit rot within one sweep,
    pays its reads to the shared IO rate limiter, and feeds quarantine.
"""
import dataclasses
import errno
import os
import threading
import time

import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.core.indexer import DistributedIndexer
from repro.core.merge import MergeRateLimiter
from repro.data.corpus import TINY, SyntheticCorpus
from repro.serving.query_scheduler import QueryRequest, QueryScheduler
from repro.storage import (ChecksumScrubber, CorruptSegment,
                           FaultInjectingDirectory, FSDirectory,
                           RAMDirectory, RetriesExhausted, RetryingDirectory,
                           RetryPolicy, SegmentStore, WriteAheadLog,
                           decode_wal, encode_wal_add, encode_wal_delete,
                           is_transient_error, open_latest,
                           open_latest_degraded, open_searcher)
from repro.storage.codec import KIND_WAL, frame
from repro.storage.commit import read_commit, write_commit
from repro.storage.wal import wal_name
from test_merge import make_segment

SMOKE_CFG = get_arch("lucene-envelope").smoke

# fast policy for tests: real backoff shape, negligible wall clock
FAST = dict(base_delay_s=1e-5, max_delay_s=1e-4)


def _tokens(rng, n=16):
    return rng.integers(1, 4096, (n, 64)).astype(np.int32)


# ---------------------------------------------------------------------------
# FaultInjectingDirectory: scripted + seeded fault engine
# ---------------------------------------------------------------------------

def test_scripted_transient_fault_fails_then_heals():
    fi = FaultInjectingDirectory(RAMDirectory())
    fi.fail_next("write", "transient", times=2)
    for _ in range(2):
        with pytest.raises(OSError) as e:
            fi.write_file("a", b"x")
        assert e.value.errno == errno.EIO
    fi.write_file("a", b"x")                 # script exhausted: succeeds
    assert fi.read_file("a") == b"x"
    assert fi.injected["transient"] == 2
    assert fi.op_counts["write"] == 3


def test_scripted_enospc_is_errno_enospc():
    fi = FaultInjectingDirectory(RAMDirectory())
    fi.fail_next("write", "enospc")
    with pytest.raises(OSError) as e:
        fi.write_file("a", b"x")
    assert e.value.errno == errno.ENOSPC
    assert fi.injected["enospc"] == 1
    fi.write_file("a", b"x")


def test_scripted_torn_write_leaves_strict_prefix():
    ram = RAMDirectory()
    fi = FaultInjectingDirectory(ram, seed=3)
    data = bytes(range(200))
    fi.fail_next("write", "torn")
    with pytest.raises(OSError):
        fi.write_file("f", data)
    assert fi.injected["torn"] == 1
    on_media = ram._files["f"]               # the kill-mid-write residue
    assert len(on_media) < len(data)
    assert data.startswith(on_media)
    fi.write_file("f", data)                 # retry lands the full bytes
    assert fi.read_file("f") == data


def test_fail_always_until_cleared_and_name_filter():
    fi = FaultInjectingDirectory(RAMDirectory())
    fi.write_file("seg.pst", b"a")
    fi.write_file("other", b"b")
    fi.fail_always("read", name_substr=".pst")
    for _ in range(3):
        with pytest.raises(OSError):
            fi.read_file("seg.pst")
    assert fi.read_file("other") == b"b"     # filter: other names untouched
    assert fi.injected["persistent"] == 3
    fi.clear_faults()
    assert fi.read_file("seg.pst") == b"a"


def test_corrupt_file_flips_exactly_one_bit():
    fi = FaultInjectingDirectory(RAMDirectory(), seed=7)
    data = b"\x00" * 64
    fi.write_file("f", data)
    bit = fi.corrupt_file("f")
    got = fi.read_file("f")
    assert got != data and len(got) == len(data)
    diff = np.unpackbits(np.frombuffer(got, np.uint8)
                         ^ np.frombuffer(data, np.uint8))
    assert diff.sum() == 1                   # exactly one bit of rot
    assert fi.injected["flip"] == 1
    fi.corrupt_file("f", bit=bit)            # flip it back: restored
    assert fi.read_file("f") == data


def test_seeded_faults_are_reproducible_and_bounded():
    """Same seed + same op sequence -> identical fault sequence; and a
    drawn transient fails exactly ``transient_repeat`` consecutive
    attempts then succeeds WITHOUT a fresh draw — the property that
    makes any retry cap >= transient_repeat provably heal."""
    def run(seed):
        fi = FaultInjectingDirectory(RAMDirectory(), seed=seed,
                                     p_transient=0.5, transient_repeat=2)
        trace = []
        for i in range(30):
            attempts = 0
            while True:
                try:
                    fi.write_file(f"f{i}", b"x")
                    break
                except OSError:
                    attempts += 1
                    assert attempts <= 2, "fault outlived transient_repeat"
            trace.append(attempts)
        return trace, fi.injected["transient"]

    t1, n1 = run(11)
    t2, n2 = run(11)
    t3, _ = run(12)
    assert t1 == t2 and n1 == n2 > 0
    assert t3 != t1                          # a different seed, different run
    assert all(a in (0, 2) for a in t1)      # drawn faults replay fully


def test_latency_spikes_sleep_and_count():
    fi = FaultInjectingDirectory(RAMDirectory(), seed=0,
                                 p_latency=1.0, latency_s=0.01)
    t0 = time.perf_counter()
    fi.write_file("a", b"x")
    assert time.perf_counter() - t0 >= 0.01
    assert fi.injected["latency"] == 1


def test_disarmed_injector_passes_through():
    fi = FaultInjectingDirectory(RAMDirectory(), p_transient=1.0)
    fi.armed = False
    for i in range(5):
        fi.write_file(f"f{i}", b"x")         # would all fault if armed
    assert fi.injected["transient"] == 0
    assert fi.op_counts["write"] == 5


# ---------------------------------------------------------------------------
# RetryPolicy / RetryingDirectory
# ---------------------------------------------------------------------------

def test_retry_policy_delay_capped_exponential():
    p = RetryPolicy(max_retries=8, base_delay_s=0.01, max_delay_s=0.05,
                    jitter=0.5, seed=0)
    for k in range(1, 9):
        d = p.delay(k)
        cap = min(0.05, 0.01 * 2 ** (k - 1))
        assert 0.5 * cap <= d <= cap         # jitter only shrinks, bounded


def test_retry_policy_call_bounds_attempts():
    calls = []

    def always_fails():
        calls.append(1)
        raise OSError(errno.EIO, "flaky")

    p = RetryPolicy(max_retries=3, **FAST)
    with pytest.raises(RetriesExhausted) as e:
        p.call(always_fails, op="write", name="f")
    assert len(calls) == 4                   # 1 try + max_retries re-tries
    assert e.value.attempts == 4
    assert isinstance(e.value.__cause__, OSError)
    assert isinstance(e.value, OSError)      # recovery walks catch it


def test_retry_policy_refuses_non_retryable():
    def enospc():
        raise OSError(errno.ENOSPC, "full")

    p = RetryPolicy(max_retries=5, **FAST)
    with pytest.raises(OSError) as e:
        p.call(enospc, op="write", name="f")
    assert e.value.errno == errno.ENOSPC     # propagated untouched, no retry
    assert not isinstance(e.value, RetriesExhausted)
    with pytest.raises(FileNotFoundError):
        p.call(lambda: (_ for _ in ()).throw(FileNotFoundError("f")),
               op="read", name="f")


def test_is_transient_error_classification():
    assert is_transient_error(OSError(errno.EIO, "x"))
    assert is_transient_error(OSError("plain"))
    assert not is_transient_error(OSError(errno.ENOSPC, "full"))
    assert not is_transient_error(FileNotFoundError("gone"))
    assert not is_transient_error(
        RetriesExhausted("w", "f", 3, OSError("x")))
    assert not is_transient_error(ValueError("not io"))


def test_retrying_directory_heals_scripted_faults():
    fi = FaultInjectingDirectory(RAMDirectory())
    rd = RetryingDirectory(fi, RetryPolicy(max_retries=3, **FAST))
    fi.fail_next("write", "transient", times=2)
    rd.write_file("a", b"payload")           # heals inside the cap
    fi.fail_next("read", "transient", times=3)
    assert rd.read_file("a") == b"payload"
    assert rd.retries == 5 and rd.giveups == 0


def test_retrying_directory_exhausts_into_typed_error():
    fi = FaultInjectingDirectory(RAMDirectory())
    rd = RetryingDirectory(fi, RetryPolicy(max_retries=2, **FAST))
    fi.fail_always("write", name_substr="doomed")
    with pytest.raises(RetriesExhausted) as e:
        rd.write_file("doomed", b"x")
    assert e.value.op == "write" and e.value.attempts == 3
    assert rd.giveups == 1 and rd.retries == 2
    assert fi.injected["persistent"] == 3    # attempts == injections: bounded
    rd.write_file("fine", b"x")              # other names unaffected


def test_retrying_directory_passes_enospc_through():
    fi = FaultInjectingDirectory(RAMDirectory())
    rd = RetryingDirectory(fi, RetryPolicy(max_retries=5, **FAST))
    fi.fail_next("write", "enospc")
    with pytest.raises(OSError) as e:
        rd.write_file("a", b"x")
    assert e.value.errno == errno.ENOSPC
    assert rd.retries == 0                   # never retried a full device


def test_retry_stack_heals_seeded_faults_statistically():
    """The stack the ISSUE names: retry cap >= transient_repeat means a
    seeded run completes with zero giveups no matter the draw."""
    fi = FaultInjectingDirectory(RAMDirectory(), seed=42,
                                 p_transient=0.4, p_torn=0.1,
                                 transient_repeat=2)
    rd = RetryingDirectory(fi, RetryPolicy(max_retries=3, **FAST))
    for i in range(60):
        rd.write_file(f"f{i:03d}", bytes([i]) * 100)
    for i in range(60):
        assert rd.read_file(f"f{i:03d}") == bytes([i]) * 100
    assert fi.injected["transient"] + fi.injected["torn"] > 0
    assert rd.retries > 0 and rd.giveups == 0


# ---------------------------------------------------------------------------
# FSDirectory: atomic writes + stale-tmp recovery sweep (satellite)
# ---------------------------------------------------------------------------

def test_fs_write_is_atomic_replace(tmp_path, monkeypatch):
    d = FSDirectory(tmp_path / "x")
    d.write_file("f", b"old-content")

    real_replace = os.replace

    def boom(src, dst):
        if os.path.basename(dst) == "f":
            raise OSError(errno.EIO, "injected replace failure")
        return real_replace(src, dst)

    monkeypatch.setattr(os, "replace", boom)
    with pytest.raises(OSError):
        d.write_file("f", b"NEW")
    monkeypatch.undo()
    assert d.read_file("f") == b"old-content"   # never a torn target
    assert d.list_files() == ["f"]              # staged tmp cleaned up
    assert not any(n.startswith(".tmp.")
                   for n in os.listdir(tmp_path / "x"))


def test_fs_sweeps_stale_tmp_files_on_recovery(tmp_path):
    p = tmp_path / "x"
    d = FSDirectory(p)
    d.write_file("keeper", b"data")
    # a writer killed mid-stage leaves its tmp behind
    (p / ".tmp.victim").write_bytes(b"half a fi")
    d2 = FSDirectory(p)                         # the restart moment
    assert d2.stale_tmps_removed == 1
    assert d2.list_files() == ["keeper"]
    assert not (p / ".tmp.victim").exists()
    assert d2.read_file("keeper") == b"data"


# ---------------------------------------------------------------------------
# WAL: encode/decode, append/replay/truncate, torn-tail skip
# ---------------------------------------------------------------------------

def test_wal_record_roundtrip():
    rng = np.random.default_rng(0)
    toks = rng.integers(-1, 500, (5, 12)).astype(np.int32)
    op, got = decode_wal(encode_wal_add(toks))
    assert op == "add" and got.dtype == np.int32 and (got == toks).all()
    ids = np.array([3, 9, 1 << 40], np.int64)
    op, got = decode_wal(encode_wal_delete(ids))
    assert op == "delete" and got.dtype == np.int64 and (got == ids).all()
    with pytest.raises(CorruptSegment):
        decode_wal(b"")
    with pytest.raises(CorruptSegment):
        decode_wal(b"Zjunk")
    with pytest.raises(CorruptSegment):
        decode_wal(encode_wal_add(toks)[:-3])   # truncated body
    with pytest.raises(ValueError):
        encode_wal_add(np.zeros(4, np.int32))   # must be (D, L)


def test_wal_append_replay_truncate():
    ram = RAMDirectory()
    w = WriteAheadLog(ram)
    rng = np.random.default_rng(1)
    toks = _tokens(rng, 3)
    assert w.append(encode_wal_add(toks)) == 0
    assert w.append(encode_wal_delete([7])) == 1
    assert w.appended == 2 and w.next_seq == 2
    assert ram.syncs == 2                       # synced before every ack
    # a fresh WAL over the same directory (the recovery moment)
    w2 = WriteAheadLog(ram)
    assert w2.next_seq == 2                     # resumes past existing seqs
    got = list(w2.replay())
    assert [(s, op) for s, op, _ in got] == [(0, "add"), (1, "delete")]
    assert (got[0][2] == toks).all() and got[1][2] == [7]
    assert w2.replayed == 2 and w2.skipped == 0
    assert w2.truncate_upto(1) == 2
    assert not any(n.startswith("wal_") for n in ram.list_files())
    assert w2.append(encode_wal_delete([1])) == 2   # seqs keep climbing


def test_wal_replay_skips_torn_tail():
    """The record mid-append at the kill was never acked: its torn frame
    fails crc and is skipped, every earlier (acked) record replays."""
    ram = RAMDirectory()
    w = WriteAheadLog(ram)
    rng = np.random.default_rng(2)
    w.append(encode_wal_add(_tokens(rng, 2)))
    full = frame(KIND_WAL, encode_wal_delete([5]))
    ram.write_file(wal_name(1), full[:len(full) - 7])    # torn tail
    ram.write_file(wal_name(2), b"")                     # fully torn
    w2 = WriteAheadLog(ram)
    got = list(w2.replay())
    assert [(s, op) for s, op, _ in got] == [(0, "add")]
    assert w2.skipped == 2
    assert w2.next_seq == 3                # never reuses a torn record's seq


def test_wal_kill9_between_ack_and_flush_loses_nothing():
    """The tentpole durability claim, deterministically: acked batches +
    deletes that never reached a flush survive a kill -9 via replay,
    with deterministic doc-id reallocation (replay order == ack order)."""
    cfg = dataclasses.replace(SMOKE_CFG, flush_budget_mb=64)  # no autoflush
    rng = np.random.default_rng(3)
    ram = RAMDirectory()
    ix = DistributedIndexer(cfg=cfg, target_dir=ram, wal=True)
    committed = _tokens(rng, 16)
    ix.index_batch(committed)
    ix.commit()                                 # covers seqs so far
    assert not any(n.startswith("wal_") for n in ram.list_files())
    acked = _tokens(rng, 8)
    ix.index_batch(acked)                       # acked, still in RAM buffer
    ix.delete([2, 17])                          # one committed, one buffered
    snapshot = dict(ram._files)                 # kill -9
    ram2 = RAMDirectory()
    ram2._files = snapshot
    ix2 = DistributedIndexer(cfg=cfg, target_dir=ram2, wal=True)
    assert ix2._wal.replayed == 2
    s = ix2.refresh()
    assert s.n_docs == 24 - 2                   # nothing acked was lost
    final = ix2.finalize()
    assert (final.doc_ids
            == np.setdiff1d(np.arange(24), [2, 17])).all()
    ix2.close()
    ix.close()


def test_wal_replay_is_idempotent_across_recoveries():
    cfg = SMOKE_CFG                             # flushes every batch
    rng = np.random.default_rng(4)
    ram = RAMDirectory()
    ix = DistributedIndexer(cfg=cfg, target_dir=ram, wal=True)
    ix.index_batch(_tokens(rng, 8))
    snap = dict(ram._files)
    ix.close()
    for _ in range(3):                          # crash-loop: replay, die, …
        ram_n = RAMDirectory()
        ram_n._files = dict(snap)
        ix_n = DistributedIndexer(cfg=cfg, target_dir=ram_n, wal=True)
        assert ix_n.refresh().n_docs == 8       # exactly once, every time
        assert ix_n._next_doc == 8
        ix_n.close()


# ---------------------------------------------------------------------------
# WAL rotation + recycling (ISSUE 9 satellite)
# ---------------------------------------------------------------------------

def test_wal_rotation_splits_oversized_adds_and_reassembles():
    ram = RAMDirectory()
    w = WriteAheadLog(ram, rotate_bytes=400)
    rng = np.random.default_rng(9)
    toks = _tokens(rng, 6)                      # 6 x 64 x i32: must split
    last = w.append(encode_wal_add(toks))
    names = [n for n in ram.list_files() if n.startswith("wal_")]
    assert len(names) == 6 and last == 5        # one 256B row per file
    assert w.rotations == 5 and w.appended == 6
    assert all(ram.file_size(n) <= 400 for n in names)
    assert ram.syncs == 6                       # every part durable pre-ack
    w.append(encode_wal_delete([3]))            # deletes never split
    w.append(encode_wal_add(toks[:1]))          # single row fits whole
    assert w.next_seq == 8
    w2 = WriteAheadLog(ram, rotate_bytes=400)
    got = list(w2.replay())
    assert [(s, op) for s, op, _ in got] \
        == [(5, "add"), (6, "delete"), (7, "add")]
    assert (got[0][2] == toks).all()            # the group reassembled
    assert (got[2][2] == toks[:1]).all()
    assert w2.replayed == 3 and w2.skipped == 0


@pytest.mark.parametrize("lost", ["head", "middle", "tail"])
def test_wal_rotation_incomplete_group_dropped_whole(lost):
    """A rotated group missing ANY part (the kill landed before the
    group's batched sync, so the batch was never acked) is dropped
    whole — a surviving tail run must never replay as a truncated
    batch. Records outside the group still replay."""
    ram = RAMDirectory()
    w = WriteAheadLog(ram, rotate_bytes=400)
    rng = np.random.default_rng(10)
    w.append(encode_wal_delete([1]))            # seq 0: intact neighbour
    w.append(encode_wal_add(_tokens(rng, 6)))   # seqs 1..6: the group
    w.append(encode_wal_delete([2]))            # seq 7: intact neighbour
    victim = {"head": 1, "middle": 3, "tail": 6}[lost]
    ram.delete_file(wal_name(victim))
    w2 = WriteAheadLog(ram)
    got = list(w2.replay())
    assert [(s, op) for s, op, _ in got] == [(0, "delete"), (7, "delete")]
    assert w2.skipped == 5                      # every surviving part
    assert w2.next_seq == 8


def test_wal_orphan_group_head_never_absorbs_next_group():
    """A group head whose continuation was lost pre-sync must not
    swallow the head of the NEXT (fully acked) group during replay."""
    ram = RAMDirectory()
    w = WriteAheadLog(ram, rotate_bytes=400)
    rng = np.random.default_rng(11)
    w.append(encode_wal_add(_tokens(rng, 2)))   # seqs 0..1
    torn = ram.read_file(wal_name(1))
    ram.write_file(wal_name(1), torn[:len(torn) - 9])   # crash mid-part 1
    w2 = WriteAheadLog(ram, rotate_bytes=400)   # recovery: next_seq = 2
    assert w2.next_seq == 2
    acked = _tokens(rng, 3)
    w2.append(encode_wal_add(acked))            # seqs 2..4, fully synced
    w3 = WriteAheadLog(ram)
    got = list(w3.replay())
    assert [(s, op) for s, op, _ in got] == [(4, "add")]
    assert (got[0][2] == acked).all()           # exact, not merged with seq 0
    assert w3.skipped == 2                      # the torn part + its head


def test_wal_recycling_parks_reuses_and_reclaims():
    ram = RAMDirectory()
    w = WriteAheadLog(ram, recycle_keep=2)
    for i in range(3):
        w.append(encode_wal_delete([i]))
    assert w.truncate_upto(2) == 3
    assert w.recycled == 2                      # 2 parked ahead, 1 deleted
    parked = sorted(n for n in ram.list_files() if n.startswith("wal_"))
    assert parked == [wal_name(3), wal_name(4)]
    assert w.append(encode_wal_delete([7])) == 3    # overwrites a park
    assert w.recycle_reused == 1
    # recovery: the live record replays; the still-stale park (its
    # embedded seq disagrees with its name) is reclaimed, never replayed
    w2 = WriteAheadLog(ram)
    got = list(w2.replay())
    assert [(s, op, int(b[0])) for s, op, b in got] == [(3, "delete", 7)]
    assert w2.recycle_reclaimed == 1 and w2.skipped == 0
    assert not ram.file_exists(wal_name(4))


def test_wal_kill9_across_rotation_loses_nothing():
    """The satellite's end-to-end claim: acked-but-unflushed ingest that
    rotated across capped record files (some overwriting recycled parks)
    survives a kill -9 exactly — same doc set, same ids."""
    cfg = dataclasses.replace(SMOKE_CFG, flush_budget_mb=64,  # no autoflush
                              wal_rotate_mb=0.001, wal_recycle=2)
    rng = np.random.default_rng(12)
    ram = RAMDirectory()
    ix = DistributedIndexer(cfg=cfg, target_dir=ram, wal=True)
    ix.index_batch(_tokens(rng, 16))
    ix.commit()                                 # truncate parks 2 files
    assert ix._wal.recycled == 2
    acked = _tokens(rng, 8)
    ix.index_batch(acked)                       # rotates, reuses the parks
    ix.delete([2, 17])
    assert ix._wal.rotations >= 2 and ix._wal.recycle_reused == 2
    rep = ix.envelope_report()
    assert rep["wal_rotations"] == ix._wal.rotations
    assert rep["wal_recycled"] == 2 and rep["wal_recycle_reused"] == 2
    snapshot = dict(ram._files)                 # kill -9
    ram2 = RAMDirectory()
    ram2._files = snapshot
    ix2 = DistributedIndexer(cfg=cfg, target_dir=ram2, wal=True)
    s = ix2.refresh()
    assert s.n_docs == 24 - 2                   # nothing acked was lost
    final = ix2.finalize()
    assert (final.doc_ids == np.setdiff1d(np.arange(24), [2, 17])).all()
    ix2.close()
    ix.close()


# ---------------------------------------------------------------------------
# quarantine + degraded serving
# ---------------------------------------------------------------------------

def _committed_dir(rng, n_batches=3):
    """RAMDirectory holding one commit of ``n_batches`` 16-doc segments."""
    ram = RAMDirectory()
    ix = DistributedIndexer(cfg=SMOKE_CFG, target_dir=ram)
    for _ in range(n_batches):
        ix.index_batch(_tokens(rng, 16))
    ix.commit()
    ix.close()
    segs = sorted({n.split(".")[0] for n in ram.list_files()
                   if n.endswith(".pst")})
    return ram, segs


def test_degraded_open_serves_survivors_and_sizes_the_loss():
    rng = np.random.default_rng(5)
    ram, segs = _committed_dir(rng)
    FaultInjectingDirectory(ram, seed=1).corrupt_file(segs[0] + ".pst")
    gen, survivors = open_latest(ram)           # strict: whole commit dead
    assert gen == 0 and survivors == []
    gen, survivors, info = open_latest_degraded(ram)
    assert gen == 1 and len(survivors) == 2
    assert info.degraded and info.quarantined == {segs[0]: 16}
    assert info.missing_docs == 16
    assert sum(s.n_docs for s in survivors) == 32


def test_degraded_flag_flows_to_searcher_and_scheduler():
    rng = np.random.default_rng(6)
    ram, segs = _committed_dir(rng)
    FaultInjectingDirectory(ram, seed=2).corrupt_file(segs[1] + ".doc")
    gen, searcher = open_searcher(ram, degraded=True)
    assert searcher.degraded and searcher.missing_docs == 16
    assert searcher.quarantined == (segs[1],)
    assert searcher.n_docs == 32
    sched = QueryScheduler(searcher=searcher, max_terms=4, k=5)
    assert sched.degraded and sched.missing_docs == 16
    req = QueryRequest(rid=0, terms=np.array([3, 5], np.int32), k=5)
    sched.submit(req)
    assert sched.step() == [req] and req.done   # traffic still flows
    # a healthy directory reports not-degraded through the same path
    ram2, _ = _committed_dir(np.random.default_rng(7))
    _, healthy = open_searcher(ram2, degraded=True)
    assert not healthy.degraded and healthy.missing_docs == 0


def test_quarantine_carries_forward_across_commits():
    """Once a segment is quarantined its loss stays visible in every
    later manifest — a degraded index never silently forgets its hole."""
    rng = np.random.default_rng(8)
    ram, segs = _committed_dir(rng)
    FaultInjectingDirectory(ram, seed=3).corrupt_file(segs[0] + ".pst")
    ix = DistributedIndexer(cfg=SMOKE_CFG, target_dir=ram, degraded_ok=True)
    assert ix.store.quarantined == {segs[0]: 16}
    assert ix.refresh().degraded
    ix.index_batch(_tokens(rng, 16))            # life goes on
    ix.commit()
    ix.close()
    # the NEW manifest is fully valid (casualty excluded), so even the
    # strict walk succeeds — but the recorded loss is carried forward
    gen, survivors = open_latest(ram)
    assert gen == 2 and sum(s.n_docs for s in survivors) == 48
    _, _, info = open_latest_degraded(ram)
    assert info.quarantined == {segs[0]: 16} and info.missing_docs == 16


def test_live_quarantined_segment_self_heals_at_commit():
    """Bit rot under a RUNNING writer costs nothing: the in-memory copy
    is authoritative, so commit rewrites the poisoned segment under a
    fresh name and the quarantine clears."""
    rng = np.random.default_rng(9)
    ram = RAMDirectory()
    ix = DistributedIndexer(cfg=SMOKE_CFG, target_dir=ram)
    ix.index_batch(_tokens(rng, 16))
    ix.index_batch(_tokens(rng, 16))
    ix.commit()
    victim = sorted({n.split(".")[0] for n in ram.list_files()
                     if n.endswith(".pst")})[0]
    FaultInjectingDirectory(ram, seed=4).corrupt_file(victim + ".pst")
    assert ix.store.quarantine(victim + ".pst")
    assert not ix.store.quarantine(victim)      # idempotent
    ix.commit()
    assert ix.store.heals == 1 and ix.store.quarantined == {}
    ix.close()
    gen, segs = open_latest(ram)                # strict walk: fully healthy
    assert sum(s.n_docs for s in segs) == 32
    _, _, info = open_latest_degraded(ram)
    assert not info.degraded                    # the hole is gone for good


def test_recovery_walk_survives_flaky_reads():
    """Satellite: an OSError mid-walk (not just a bad checksum) skips
    that commit and keeps walking instead of aborting recovery."""
    rng = np.random.default_rng(10)
    ram = RAMDirectory()
    seg_old = make_segment(rng, 0, n_docs=4)
    store = SegmentStore(directory=ram)
    store.write(seg_old)
    store.commit([seg_old])                     # gen 1
    seg_new = make_segment(rng, 100, n_docs=4)
    store.write(seg_new)
    write_commit(ram, 2, [store._names[seg_new.seg_id]])  # gen 2, by hand
    fi = FaultInjectingDirectory(ram)
    fi.fail_always("read", name_substr="segments_2")
    gen, segs, info = open_latest_degraded(fi)
    assert gen == 1 and len(segs) == 1          # fell back past the EIO
    assert segs[0].n_docs == 4
    assert info.commits_skipped == 1 and info.io_errors == 1
    assert not info.degraded                    # fallback commit is whole


# ---------------------------------------------------------------------------
# checksum scrubber
# ---------------------------------------------------------------------------

def test_scrubber_clean_sweep_verifies_every_committed_byte():
    rng = np.random.default_rng(11)
    ram, segs = _committed_dir(rng)
    lim = MergeRateLimiter(mb_per_s=10_000.0)
    sc = ChecksumScrubber(ram, limiter=lim)
    assert sc.sweep() == []
    rep = sc.report()
    # manifest + every suffix of every segment
    assert rep["files_checked"] >= 1 + 3 * len(segs)
    assert rep["bytes_verified"] > 0 and rep["corrupt_found"] == 0
    assert lim.bytes_charged == rep["bytes_verified"]   # reads pay the toll


def test_scrubber_finds_bit_rot_within_one_sweep_and_quarantines():
    rng = np.random.default_rng(12)
    ram, segs = _committed_dir(rng)
    store, _ = SegmentStore.open(ram, degraded=True)
    hits = []
    sc = ChecksumScrubber(ram, store=store, on_corrupt=hits.append)
    assert sc.sweep() == []
    FaultInjectingDirectory(ram, seed=5).corrupt_file(segs[2] + ".dict")
    found = sc.sweep()
    assert found == [segs[2] + ".dict"] and hits == found
    assert store.quarantined == {segs[2]: 16}   # fed straight to quarantine
    assert sc.report()["corrupt_found"] == 1
    # the quarantined segment is excluded from later sweeps (known-bad)
    checked_before = sc.report()["files_checked"]
    assert sc.sweep() == []
    assert sc.report()["files_checked"] < checked_before + checked_before


def test_scrubber_daemon_detects_and_writer_self_heals():
    """The full loop: background scrubber spots rot on a live index, the
    next commit self-heals it, and a strict recovery sees every doc."""
    rng = np.random.default_rng(13)
    ram = RAMDirectory()
    ix = DistributedIndexer(cfg=SMOKE_CFG, target_dir=ram,
                            scrub_every=0.01, scrub_io_mbps=10_000.0)
    ix.index_batch(_tokens(rng, 16))
    ix.index_batch(_tokens(rng, 16))
    ix.commit()
    victim = sorted({n.split(".")[0] for n in ram.list_files()
                     if n.endswith(".pst")})[0]
    FaultInjectingDirectory(ram, seed=6).corrupt_file(victim + ".pos")
    deadline = time.time() + 10
    while not ix.store.quarantined and time.time() < deadline:
        time.sleep(0.01)
    assert ix.store.quarantined == {victim: 16}, "scrubber missed the rot"
    ix.commit()                                 # self-heal
    assert ix.store.heals == 1
    rep = ix.envelope_report()
    assert rep["scrub_corrupt_found"] >= 1 and rep["scrub_sweeps"] >= 1
    assert rep["segments_healed"] == 1
    ix.close()                                  # daemon error would re-raise
    gen, segs = open_latest(ram)
    assert sum(s.n_docs for s in segs) == 32


# ---------------------------------------------------------------------------
# the hardened stack end to end
# ---------------------------------------------------------------------------

def test_indexer_retry_policy_wraps_target_and_reports():
    """retry_policy on the indexer hardens the WHOLE write path — flush,
    .liv writes, commit — and the envelope report shows the retry cost."""
    rng = np.random.default_rng(14)
    fi = FaultInjectingDirectory(RAMDirectory(), seed=21,
                                 p_transient=0.15, p_torn=0.05,
                                 transient_repeat=2)
    ix = DistributedIndexer(cfg=SMOKE_CFG, target_dir=fi, wal=True,
                            retry_policy=RetryPolicy(max_retries=3, **FAST))
    assert isinstance(ix.target_dir, RetryingDirectory)
    for i in range(4):
        ix.index_batch(_tokens(rng, 16))
        ix.delete([i * 16])
    ix.commit()
    rep = ix.envelope_report()
    assert rep["io_retries"] > 0 and rep["io_giveups"] == 0
    assert rep["wal_appends"] == 8
    assert not rep["degraded"] and rep["missing_docs"] == 0
    ix.close()
    gen, segs = open_latest(fi.inner)           # media is clean underneath
    s = open_searcher(fi.inner)[1]
    assert s.n_docs == 64 - 4


def test_enospc_fails_fast_through_the_whole_stack():
    """A full device is not retried anywhere: the writer sees the ENOSPC
    on the op that hit it, with zero retry attempts burned. The raised
    ``index_batch`` is NOT an ack — its batch is simply not in the index,
    and the writer stays consistent for the batches that follow."""
    rng = np.random.default_rng(15)
    fi = FaultInjectingDirectory(RAMDirectory())
    ix = DistributedIndexer(cfg=SMOKE_CFG, target_dir=fi,
                            retry_policy=RetryPolicy(max_retries=5, **FAST))
    ix.index_batch(_tokens(rng, 16))
    fi.fail_next("write", "enospc", times=1)
    with pytest.raises(OSError) as e:
        ix.index_batch(_tokens(rng, 16))        # flush hits the full device
    assert e.value.errno == errno.ENOSPC
    assert ix.target_dir.retries == 0
    fi.clear_faults()
    ix.index_batch(_tokens(rng, 16))            # space freed: writer resumes
    ix.commit()
    ix.close()
    # only the two ACKED batches are served; the failed one never was
    assert open_searcher(fi.inner)[1].n_docs == 32
