"""Document lifecycle end-to-end: tombstoned deletes & updates across the
write, merge, storage and read paths.

The acceptance invariant (ISSUE 4): after ANY interleaving of index /
delete / update / flush / merge / commit / recover, ``IndexSearcher``
results are bit-identical to searching the force-merged compacted index
built from only the live docs (hypothesis interleaving oracle below), and
a deleted doc is never returned from any snapshot taken after its delete
was acknowledged.
"""
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_arch
from repro.core.indexer import DistributedIndexer
from repro.core.merge import MergeDriver, drop_deleted, merge_segments
from repro.core.query import bm25_exhaustive
from repro.core.searcher import ReaderCache, build_block_index
from repro.data.corpus import TINY, SyntheticCorpus
from repro.storage import (FaultInjectingDirectory, RAMDirectory,
                           RetryPolicy, open_latest)
from test_merge import ARRAY_FIELDS, assert_bit_identical, make_segment

SMOKE_CFG = get_arch("lucene-envelope").smoke


# ---------------------------------------------------------------------------
# Segment.with_deletes semantics
# ---------------------------------------------------------------------------

def test_with_deletes_copy_on_write():
    rng = np.random.default_rng(0)
    s = make_segment(rng, 100, n_docs=8)
    assert s.with_deletes([]) is s
    assert s.with_deletes([99999]) is s          # id not in this segment
    s2 = s.with_deletes([101, 104])
    assert s2 is not s and s2.seg_id != s.seg_id
    assert s2.base_id == s.base_id               # same postings core
    assert s.deletes is None                     # original untouched
    assert s2.live_doc_count == 6 and s2.n_deleted == 2
    assert (s2.live_doc_ids() == [100, 102, 103, 105, 106, 107]).all()
    for f in ARRAY_FIELDS:
        assert getattr(s2, f) is getattr(s, f)   # zero-copy postings
    # idempotent re-application returns the same object (cache-friendly)
    assert s2.with_deletes([101]) is s2
    assert s2.with_deletes([101, 99999]) is s2
    # union with new ids makes a third generation
    s3 = s2.with_deletes([101, 107])
    assert s3.n_deleted == 3 and s2.n_deleted == 2
    # byte accounting carries over (the postings core is unchanged)
    assert s3.total_bytes() == s.total_bytes()


def test_drop_deleted_is_identity_without_deletes():
    rng = np.random.default_rng(1)
    s = make_segment(rng, 0, n_docs=5)
    assert drop_deleted(s) is s


def test_drop_deleted_filters_all_streams():
    rng = np.random.default_rng(2)
    s = make_segment(rng, 0, n_docs=6, vocab=20, max_terms=8)
    dead = s.doc_ids[::2]
    d = drop_deleted(s.with_deletes(dead))
    assert not d.has_deletes
    assert (d.doc_ids == s.doc_ids[1::2]).all()
    assert not np.isin(d.docs, dead).any()
    # every surviving (term, doc) position run is verbatim
    runs = {}
    for ti, t in enumerate(s.terms):
        for j in range(s.term_start[ti], s.term_start[ti + 1]):
            runs[(int(t), int(s.docs[j]))] = \
                s.positions[s.pos_start[j]:s.pos_start[j + 1]].tolist()
    for ti, t in enumerate(d.terms):
        assert d.term_start[ti + 1] > d.term_start[ti]  # no empty terms
        for j in range(d.term_start[ti], d.term_start[ti + 1]):
            got = d.positions[d.pos_start[j]:d.pos_start[j + 1]].tolist()
            assert got == runs[(int(t), int(d.docs[j]))]


# ---------------------------------------------------------------------------
# MergeDriver: deletes routed everywhere, including in-flight claims
# ---------------------------------------------------------------------------

def test_apply_deletes_reaches_tiers_and_inflight():
    """No delete may be lost mid-merge: ids applied while a batch is
    claimed must be visible in every snapshot AND folded into the merge
    output at install, even though the worker read the old inputs."""
    rng = np.random.default_rng(3)
    a = make_segment(rng, 0, n_docs=6)
    b = make_segment(rng, 100, n_docs=6)
    c = make_segment(rng, 200, n_docs=6)
    drv = MergeDriver(fanout=2)
    drv.tiers = {0: [a, b], 1: [c]}
    work = drv.pop_merge_work()          # claims [a, b]
    assert {s.doc_ids[0] for s in work.batch} == {0, 100}
    changed = drv.apply_deletes([0, 1, 100, 200, 999])
    assert changed == 3                  # a, b (in flight) and c (tier)
    live = drv.live_segments()           # snapshot during the merge
    live_ids = np.concatenate([s.live_doc_ids() for s in live])
    assert not np.isin([0, 1, 100, 200], live_ids).any()
    merged = drv.run_merge(work)         # deferred ids fold into output
    assert not np.isin([0, 1, 100], merged.live_doc_ids()).any()
    final = drv.finalize()
    assert not final.has_deletes
    want = np.sort(np.concatenate([s.doc_ids for s in (a, b, c)]))
    want = want[~np.isin(want, [0, 1, 100, 200])]
    assert (final.doc_ids == want).all()


def test_finalize_compacts_a_lone_deleted_segment():
    rng = np.random.default_rng(4)
    s = make_segment(rng, 0, n_docs=6)
    drv = MergeDriver(fanout=10)
    drv.add_flush(s)
    drv.apply_deletes(s.doc_ids[:2])
    final = drv.finalize()
    assert not final.has_deletes
    assert (final.doc_ids == s.doc_ids[2:]).all()
    assert final.generation == s.generation + 1


# ---------------------------------------------------------------------------
# the interleaving oracle (the PR's acceptance invariant)
# ---------------------------------------------------------------------------

def _check_snapshot(searcher, docs_tokens: dict, deleted: set, rng, k=10):
    """``searcher`` must behave exactly like the force-merged compacted
    index over the live docs: same top-k scores as a from-scratch BM25
    oracle, every returned id live and carrying its true global score."""
    live_ids = np.array(sorted(set(docs_tokens) - deleted), np.int64)
    assert searcher.n_docs == live_ids.size
    if live_ids.size == 0:
        return
    tokens = np.stack([docs_tokens[i] for i in live_ids])
    vocab = np.unique(tokens[tokens > 0])
    if vocab.size == 0:
        return
    from test_searcher import bm25_oracle
    for _ in range(3):
        q = rng.choice(vocab, size=min(3, vocab.size),
                       replace=False).astype(np.int32)
        kk = min(k, live_ids.size)
        v, ids = searcher.search(q, kk)
        v, ids = np.asarray(v), np.asarray(ids)
        returned = ids[ids >= 0]
        assert np.isin(returned, live_ids).all(), \
            "a deleted doc surfaced after its delete was acknowledged"
        oracle = bm25_oracle(tokens, q)          # rows follow live_ids
        np.testing.assert_allclose(v, np.sort(oracle)[::-1][:kk],
                                   rtol=1e-4, atol=1e-5)
        # tie-robust: each returned doc carries its true global score
        row = np.searchsorted(live_ids, returned)
        np.testing.assert_allclose(oracle[row], v[:returned.size],
                                   rtol=1e-4, atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 100000))
def test_lifecycle_interleaving_oracle(seed):
    """Random interleavings of index/delete/update/flush/refresh/commit/
    recover: every snapshot equals the compacted from-scratch index and
    never returns a deleted doc; recovery reproduces the committed
    lifecycle state exactly."""
    rng = np.random.default_rng(seed)
    cfg = SMOKE_CFG
    corpus = SyntheticCorpus(TINY, doc_buffer_len=cfg.doc_len)
    directory = RAMDirectory()
    ix = DistributedIndexer(cfg=cfg, target_dir=directory)
    docs_tokens, deleted = {}, set()
    committed = None                      # (docs_tokens, deleted) at commit
    batch_i = 0
    for _ in range(12):
        op = rng.choice(["index", "delete", "update", "check",
                         "commit", "recover"],
                        p=[0.35, 0.2, 0.15, 0.15, 0.1, 0.05])
        if op == "index":
            n = int(rng.integers(1, 6))
            toks = corpus.batch(batch_i, 32)[:n]
            batch_i += 1
            base = ix._next_doc + ix._flush_policy.pending_docs
            ix.index_batch(toks)
            for j in range(n):
                docs_tokens[base + j] = toks[j]
        elif op == "delete" and docs_tokens:
            pool = np.array(sorted(docs_tokens), np.int64)
            m = int(rng.integers(1, min(4, pool.size) + 1))
            ids = rng.choice(pool, size=m, replace=False)
            ix.delete(ids)
            deleted.update(int(i) for i in ids)
        elif op == "update" and docs_tokens:
            live = sorted(set(docs_tokens) - deleted)
            if not live:
                continue
            victim = int(rng.choice(live))
            toks = corpus.batch(batch_i, 32)[0]
            batch_i += 1
            new_id = ix._next_doc + ix._flush_policy.pending_docs
            ix.update(victim, toks)
            deleted.add(victim)
            docs_tokens[new_id] = toks
        elif op == "check":
            _check_snapshot(ix.refresh(), docs_tokens, deleted, rng)
        elif op == "commit":
            ix.commit()
            committed = (dict(docs_tokens), set(deleted))
        elif op == "recover" and committed is not None:
            _, segs = open_latest(directory)
            s = ReaderCache().refresh(segs)
            _check_snapshot(s, committed[0], committed[1], rng)
    # end state: snapshot, the force-merged compacted index, and a final
    # recovery must all agree
    _check_snapshot(ix.refresh(), docs_tokens, deleted, rng)
    if set(docs_tokens) - deleted:
        final = ix.finalize()
        assert not final.has_deletes
        live_ids = np.array(sorted(set(docs_tokens) - deleted))
        assert (final.doc_ids == live_ids).all()
        _check_snapshot(ix.refresh(flush=False), docs_tokens, deleted, rng)
        _, segs = open_latest(directory)
        s = ReaderCache().refresh(segs)
        _check_snapshot(s, docs_tokens, deleted, rng)


def test_multisegment_with_deletes_equals_compacted_merge():
    """Direct statement of the bit-identity half of the invariant: the
    live multi-segment searcher's scores equal exhaustive BM25 over the
    single compacted merge of the same segments."""
    rng = np.random.default_rng(7)
    import jax.numpy as jnp
    segs = []
    for i in range(4):
        s = make_segment(rng, i * 1000, n_docs=int(rng.integers(2, 9)),
                         vocab=40)
        if rng.random() < 0.8:
            n_del = int(rng.integers(1, s.n_docs))
            s = s.with_deletes(rng.choice(s.doc_ids, size=n_del,
                                          replace=False))
        segs.append(s)
    searcher = ReaderCache().refresh(segs)
    merged = merge_segments(list(segs))
    assert searcher.n_docs == merged.n_docs
    midx = build_block_index(merged)
    vocab = np.unique(np.concatenate([s.terms for s in segs]))
    for _ in range(6):
        q = rng.choice(vocab, size=3, replace=False).astype(np.int32)
        kk = min(8, merged.n_docs)
        v_m, _, _ = bm25_exhaustive(midx, jnp.asarray(q), kk)
        v_s, i_s = searcher.search(q, kk)
        np.testing.assert_allclose(np.asarray(v_s), np.asarray(v_m),
                                   rtol=1e-5, atol=1e-6)
        dead = np.concatenate([s.doc_ids[s.deletes] for s in segs
                               if s.has_deletes])
        ids = np.asarray(i_s)
        assert not np.isin(ids[ids >= 0], dead).any()


# ---------------------------------------------------------------------------
# write-path semantics
# ---------------------------------------------------------------------------

def test_delete_of_still_buffered_doc_survives_to_flush():
    """A delete acknowledged while its target doc is still in the RAM
    buffer must not be dropped by an intervening refresh: the buffer only
    drains at flush, where the delete finally lands on the new segment."""
    import dataclasses
    cfg = dataclasses.replace(SMOKE_CFG, flush_budget_mb=64)  # no autoflush
    corpus = SyntheticCorpus(TINY, doc_buffer_len=cfg.doc_len)
    ix = DistributedIndexer(cfg=cfg)
    ix.index_batch(corpus.batch(0, 8))   # docs 0..7 buffered, not flushed
    ix.delete([3])
    s = ix.refresh(flush=False)          # applies deletes, target unflushed
    assert s.n_docs == 0
    s = ix.refresh(flush=True)           # buffer flushes, delete lands
    assert s.n_docs == 7
    _, ids = s.search(np.unique(corpus.batch(0, 8))[1:3].astype(np.int32),
                      8)
    ids = np.asarray(ids)
    assert 3 not in ids[ids >= 0]
    assert ix._buffered_deletes.size == 0  # drained with the flush


def test_update_replaces_content_under_new_id():
    cfg = SMOKE_CFG
    corpus = SyntheticCorpus(TINY, doc_buffer_len=cfg.doc_len)
    ix = DistributedIndexer(cfg=cfg)
    b0 = corpus.batch(0, 16)
    ix.index_batch(b0)
    new_doc = corpus.batch(5, 16)[0]
    ix.update(2, new_doc)
    s = ix.refresh()
    assert s.n_docs == 16                # one out, one in
    q = np.unique(new_doc[new_doc > 0])[:2].astype(np.int32)
    v, ids = s.search(q, 16)
    hit = np.asarray(ids)[np.asarray(v) > 0]
    assert 16 in hit                     # replacement got the fresh id 16
    assert 2 not in hit
    assert ix.stats.updates == 1


def test_deletes_survive_synchronous_merge_cascade():
    """fanout segments + deletes + the cascade that merges them: the
    merge output must physically drop the tombstoned docs."""
    cfg = SMOKE_CFG                       # merge_fanout=4, flush per batch
    corpus = SyntheticCorpus(TINY, doc_buffer_len=cfg.doc_len)
    ix = DistributedIndexer(cfg=cfg)
    for i in range(3):
        ix.index_batch(corpus.batch(i, 8))
    ix.delete([0, 9, 17])
    ix.refresh()
    ix.index_batch(corpus.batch(3, 8))   # 4th flush -> cascade merges all
    assert ix.merger.n_merges == 1
    merged = ix.merger.live_segments()[0]
    assert merged.n_docs == 29 and not merged.has_deletes
    assert not np.isin([0, 9, 17], merged.doc_ids).any()


# ---------------------------------------------------------------------------
# NRT refresh daemon under concurrent deletes (satellite)
# ---------------------------------------------------------------------------

def test_refresh_daemon_swaps_searcher_and_joins():
    cfg = SMOKE_CFG
    corpus = SyntheticCorpus(TINY, doc_buffer_len=cfg.doc_len)
    ix = DistributedIndexer(cfg=cfg, refresh_every=0.02)
    assert ix._refresh_thread is not None and ix._refresh_thread.is_alive()
    ix.index_batch(corpus.batch(0, 16))
    deadline = time.time() + 10
    while (ix.searcher is None or ix.searcher.n_docs < 16) \
            and time.time() < deadline:
        time.sleep(0.01)
    assert ix.searcher is not None and ix.searcher.n_docs == 16
    thread = ix._refresh_thread
    ix.close()
    assert not thread.is_alive() and ix._refresh_thread is None
    assert ix.stats.refreshes > 0


def test_refresh_daemon_stress_with_concurrent_deletes():
    """Ingest + deletes from the main thread race the refresh daemon and
    a reader thread: every published snapshot must exclude every delete
    acknowledged before that snapshot was taken (checked via a monotonic
    high-water mark of acknowledged deletions), with no exceptions and a
    clean stop/join."""
    cfg = SMOKE_CFG
    corpus = SyntheticCorpus(TINY, doc_buffer_len=cfg.doc_len)
    ix = DistributedIndexer(cfg=cfg, merge_threads=2, refresh_every=0.005)
    errors, stop = [], threading.Event()
    acked = []                            # ids acked, in ack order

    def reader():
        try:
            while not stop.is_set():
                n_acked = len(acked)      # BEFORE taking the snapshot
                s = ix.searcher
                if s is None:
                    continue
                # any delete acked before this loop iteration started is
                # covered iff the snapshot postdates its refresh; assert
                # the weaker, still-sharp property on a fresh snapshot:
                s2 = ix.refresh(flush=False)
                banned = np.array(acked[:n_acked], np.int64)
                if banned.size and s2.n_docs:
                    q = np.unique(corpus.batch(0, 16))[1:4].astype(np.int32)
                    _, ids = s2.search(q, min(20, s2.n_docs))
                    ids = np.asarray(ids)
                    assert not np.isin(ids[ids >= 0], banned).any()
        except Exception as e:
            errors.append(e)

    t = threading.Thread(target=reader)
    t.start()
    try:
        for i in range(12):
            ix.index_batch(corpus.batch(i, 16))
            if i % 2:
                ids = [i * 16 - 3, i * 16 - 7]
                ix.delete(ids)
                acked.extend(ids)
    finally:
        stop.set()
        t.join(timeout=60)
    assert not t.is_alive() and not errors, errors
    ix.close()
    final = ix.finalize()
    assert final.n_docs == 12 * 16 - len(acked)
    assert not np.isin(np.array(acked), final.doc_ids).any()


# ---------------------------------------------------------------------------
# crash/fault interleaving oracle (ISSUE 7's acceptance invariant)
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(0, 100000))
def test_crash_fault_recovery_oracle(seed):
    """Random kill-9 points interleaved with seeded transient/torn IO
    faults on the hardened stack (WAL + retrying directory): after EVERY
    recovery, each acked op is present exactly once — acked adds
    searchable, acked deletes applied, nothing duplicated by replay —
    and retries stay bounded by the policy cap (zero giveups, because
    the injector heals any drawn fault within ``transient_repeat``
    consecutive failures; ``sync`` is a compound op — its existence
    check gates ``list`` too — so two drawn faults can stack and the
    provable-heal cap is ``2 * transient_repeat``)."""
    rng = np.random.default_rng(seed)
    cfg = SMOKE_CFG

    def build(files=None):
        ram = RAMDirectory()
        if files is not None:
            ram._files = dict(files)
        fi = FaultInjectingDirectory(ram, seed=seed, p_transient=0.08,
                                     p_torn=0.04, transient_repeat=2)
        ix = DistributedIndexer(
            cfg=cfg, target_dir=fi, wal=True,
            retry_policy=RetryPolicy(max_retries=5, base_delay_s=1e-5,
                                     max_delay_s=1e-4, seed=seed))
        return ram, ix

    ram, ix = build()
    acked, deleted = set(), set()          # doc ids whose ops were ACKED
    crashes = 0
    for _ in range(10):
        op = rng.choice(["index", "delete", "commit", "crash", "check"],
                        p=[0.45, 0.2, 0.1, 0.15, 0.1])
        if op == "index":
            n = int(rng.integers(1, 5))
            toks = rng.integers(1, 512, (n, cfg.doc_len)).astype(np.int32)
            base = ix._next_doc + ix._flush_policy.pending_docs
            ix.index_batch(toks)           # returning == the ack
            acked.update(range(base, base + n))
        elif op == "delete" and acked - deleted:
            pool = np.array(sorted(acked - deleted), np.int64)
            ids = rng.choice(pool, size=min(2, pool.size), replace=False)
            ix.delete(ids)                 # returning == the ack
            deleted.update(int(i) for i in ids)
        elif op == "commit":
            ix.commit()
        elif op == "crash":
            snapshot = dict(ram._files)    # kill -9: media state only
            crashes += 1
            ram, ix = build(snapshot)      # WAL replay + commit recovery
            assert ix.target_dir.giveups == 0
            assert ix.refresh().n_docs == len(acked - deleted)
        elif op == "check":
            assert ix.refresh().n_docs == len(acked - deleted)
    # one final crash so every example exercises recovery at least once
    ram, ix = build(dict(ram._files))
    crashes += 1
    assert crashes >= 1
    assert ix.target_dir.giveups == 0      # retries bounded by the cap
    live = np.array(sorted(acked - deleted), np.int64)
    assert ix.refresh().n_docs == live.size
    if live.size:
        final = ix.finalize()              # exact doc ids, exactly once
        assert (final.doc_ids == live).all()
        assert np.unique(final.doc_ids).size == live.size
    ix.close()
