"""Concurrent write pipeline: the ConcurrentMergeScheduler must keep
``add_flush``/``index_batch`` stall-free while merges run on background
threads, ``live_segments()`` snapshots must stay complete at every instant
(in-flight merge inputs remain searchable), and the end state must be
bit-identical to the synchronous write path."""
import threading
import time

import numpy as np
import pytest

import repro.core.merge as merge_mod
from repro.configs.registry import get_arch
from repro.core.indexer import DistributedIndexer
from repro.core.merge import (ConcurrentMergeScheduler, MergeDriver,
                              MergeRateLimiter, merge_segments)
from repro.data.corpus import TINY, SyntheticCorpus
from test_merge import ARRAY_FIELDS, make_segment

SLOW = 0.4  # artificial merge duration (s); flushes must not feel it


def slow_merge(segs):
    time.sleep(SLOW)
    return merge_segments(segs)


@pytest.fixture
def slow_merges(monkeypatch):
    monkeypatch.setattr(merge_mod, "merge_segments", slow_merge)


def _flush_n(drv, n, rng, n_docs=4, spacing=1000):
    segs = [make_segment(rng, i * spacing, n_docs=n_docs)
            for i in range(n)]
    for s in segs:
        drv.add_flush(s)
    return segs


def test_flush_does_not_block_on_merge(slow_merges):
    drv = MergeDriver(fanout=2)
    sched = ConcurrentMergeScheduler(drv, max_threads=2)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    _flush_n(drv, 2, rng)  # second flush fills tier 0 -> background merge
    elapsed = time.perf_counter() - t0
    assert elapsed < SLOW / 2, \
        f"flush stalled {elapsed:.3f}s behind a {SLOW}s merge"
    sched.drain()
    assert drv.n_merges == 1
    assert drv.merge_wall_s >= SLOW  # measured wall-clock includes the merge
    sched.close()


def test_live_segments_complete_mid_merge(slow_merges):
    drv = MergeDriver(fanout=2)
    sched = ConcurrentMergeScheduler(drv, max_threads=1)
    rng = np.random.default_rng(1)
    segs = _flush_n(drv, 2, rng, n_docs=5)
    all_docs = np.sort(np.concatenate([s.doc_ids for s in segs]))
    deadline = time.time() + 5
    while not drv._in_flight and time.time() < deadline:
        time.sleep(0.01)  # wait for a worker to claim the batch
    assert drv._in_flight, "merge was never claimed"
    live = drv.live_segments()  # snapshot while the merge is running
    got = np.sort(np.concatenate([s.doc_ids for s in live]))
    assert (got == all_docs).all(), "docs vanished during an in-flight merge"
    sched.drain()
    live = drv.live_segments()
    assert len(live) == 1 and live[0].generation == 1
    assert (np.sort(live[0].doc_ids) == all_docs).all()
    sched.close()


def test_failed_merge_restores_inputs(monkeypatch):
    def boom(segs):
        raise RuntimeError("merge exploded")

    monkeypatch.setattr(merge_mod, "merge_segments", boom)
    drv = MergeDriver(fanout=2)
    sched = ConcurrentMergeScheduler(drv, max_threads=1)
    rng = np.random.default_rng(2)
    segs = _flush_n(drv, 2, rng)
    with pytest.raises(RuntimeError, match="merge exploded"):
        sched.drain()
    live = drv.live_segments()  # inputs back in their tier, nothing lost
    assert {s.seg_id for s in live} == {s.seg_id for s in segs}
    assert not drv._in_flight and drv.n_merges == 0
    sched.pool.shutdown(wait=True)


def test_transient_merge_failure_heals_on_retry(monkeypatch):
    """A merge that fails once then succeeds must converge: the retried
    batch clears its recorded error, so once the index is healthy no
    stale exception ever surfaces from drain()/close()."""
    calls = []

    def flaky(segs):
        calls.append(1)
        if len(calls) == 1:
            raise RuntimeError("transient")
        return merge_segments(segs)

    monkeypatch.setattr(merge_mod, "merge_segments", flaky)
    drv = MergeDriver(fanout=2)
    sched = ConcurrentMergeScheduler(drv, max_threads=1)
    rng = np.random.default_rng(5)
    segs = _flush_n(drv, 2, rng)
    # depending on which notify claims the retry, the first drain either
    # already sees the healed index or surfaces the transient error once
    try:
        sched.drain()
    except RuntimeError:
        assert drv.n_merges == 0  # raised only while still unhealed
        sched.drain()             # retry heals
    assert drv.n_merges == 1 and len(calls) == 2
    merged = drv.live_segments()
    assert len(merged) == 1
    all_docs = np.sort(np.concatenate([s.doc_ids for s in segs]))
    assert (merged[0].doc_ids == all_docs).all()
    sched.drain()  # healthy index: no stale error re-raised
    sched.close()


def test_finalize_drains_inflight_merges(slow_merges):
    drv = MergeDriver(fanout=2)
    sched = ConcurrentMergeScheduler(drv, max_threads=2)
    rng = np.random.default_rng(3)
    segs = _flush_n(drv, 4, rng)  # two background merges + final cascade
    final = drv.finalize()
    all_docs = np.sort(np.concatenate([s.doc_ids for s in segs]))
    assert (final.doc_ids == all_docs).all()
    assert drv.live_segments() == [final]
    assert not drv._in_flight
    sched.close()


def _interleaved_ingest(merge_threads, n_batches=12, search_every=3):
    cfg = get_arch("lucene-envelope").smoke  # flushes every batch, fanout=4
    corpus = SyntheticCorpus(TINY, doc_buffer_len=cfg.doc_len)
    ix = DistributedIndexer(cfg=cfg, merge_threads=merge_threads)
    hits = []
    for i in range(n_batches):
        b = corpus.batch(i, 32)
        ix.index_batch(b)
        if i % search_every == 0:  # refresh + search mid-cascade
            s = ix.refresh()
            q = np.unique(b[b > 0])[:3].astype(np.int32)
            v, ids = s.search(q, 10)
            hits.append(np.asarray(v))  # scores are partition-independent
            assert s.n_docs == 32 * (i + 1)
    return ix, hits


def test_concurrent_pipeline_matches_sync_end_state():
    sync, hits_s = _interleaved_ingest(merge_threads=0)
    conc, hits_c = _interleaved_ingest(merge_threads=2)
    for a, b in zip(hits_s, hits_c):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)
    fs, fc = sync.finalize(), conc.finalize()
    for f in ARRAY_FIELDS:
        x, y = getattr(fs, f), getattr(fc, f)
        assert x.dtype == y.dtype and x.shape == y.shape and (x == y).all(), f
    assert sync.merger.flushed_bytes == conc.merger.flushed_bytes
    assert conc.merger.merge_wall_s > 0
    assert conc.envelope_report()["merge_concurrency"] == 2
    conc.close()


def test_merge_rate_limiter_paces_and_caps_pauses():
    lim = MergeRateLimiter(mb_per_s=1.0, max_pause_s=0.05)
    t0 = time.perf_counter()
    slept = lim.charge(30_000)           # 30ms of debt at 1 MB/s
    assert 0.02 <= slept <= 0.05
    assert time.perf_counter() - t0 >= slept
    assert lim.charge(10_000_000) == pytest.approx(0.05)  # capped
    assert lim.paused_s == pytest.approx(slept + 0.05, rel=0.3)
    assert lim.bytes_charged == 10_030_000
    assert lim.charge(10) == 0.0         # sub-threshold: no sleep


def test_merge_io_throttle_keeps_flush_p99_bounded(tmp_path):
    """The ioThrottle satellite: background merges on the `disk` profile
    pay their IO at a capped rate (sleeping on the merge worker), so
    ingest flushes never queue behind an entire cascade — flush p99 under
    a concurrent throttled merge stays bounded near the no-merge flush
    cost, while the limiter demonstrably paced real merge bytes."""
    import dataclasses
    from repro.storage import (DeviceThrottle, FSDirectory, MEDIA_PROFILES,
                               ThrottledDirectory)
    # raw codec: flush latency then measures the write PATH, not the pfor
    # packer's per-shape jit compiles (which would drown the signal)
    cfg = dataclasses.replace(get_arch("lucene-envelope").smoke,
                              codec="raw")
    corpus = SyntheticCorpus(TINY, doc_buffer_len=cfg.doc_len)
    tgt = ThrottledDirectory(FSDirectory(tmp_path / "idx"),
                             DeviceThrottle(MEDIA_PROFILES["disk"]))
    ix = DistributedIndexer(cfg=cfg, target="xfs", target_dir=tgt,
                            merge_threads=2, merge_io_mbps=0.05)
    ix.index_batch(corpus.batch(0, 32))   # warm the jit compile caches
    lat = []
    for i in range(1, 10):
        t0 = time.perf_counter()
        ix.index_batch(corpus.batch(i, 32))
        lat.append(time.perf_counter() - t0)
    if ix.merge_scheduler is not None:
        ix.merge_scheduler.drain()
    assert ix.merger.n_merges >= 1, "need a concurrent merge to throttle"
    lim = ix.merger.io_limiter
    assert lim is not None and lim.bytes_charged > 0
    assert lim.paused_s > 0, "merge IO was never paced"
    # p99 flush latency (here: the max) stays bounded: a merge at
    # 0.05 MB/s would hold the device for seconds if flushes had to queue
    # behind it; decoupled + paced, every flush stays near its own cost
    p99 = sorted(lat)[-1]
    assert p99 < 2.0, f"flush stalled {p99:.2f}s behind a throttled merge"
    rep = ix.envelope_report()
    assert rep["merge_io_paused_s"] == pytest.approx(lim.paused_s)
    ix.finalize()
    ix.close()


def test_refresh_with_flush_races_ingest_safely():
    """refresh(flush=True) from a search thread must not race the ingest
    thread's flush: doc-id allocation is serialized, so every flushed
    segment keeps a disjoint range (merge_segments asserts on it)."""
    import dataclasses
    cfg = dataclasses.replace(get_arch("lucene-envelope").smoke,
                              flush_budget_mb=1)  # buffer across batches
    corpus = SyntheticCorpus(TINY, doc_buffer_len=cfg.doc_len)
    ix = DistributedIndexer(cfg=cfg, merge_threads=2)
    stop = threading.Event()
    errors = []

    def refresher():
        try:
            while not stop.is_set():
                ix.refresh(flush=True)  # may flush concurrently with ingest
        except Exception as e:
            errors.append(e)

    t = threading.Thread(target=refresher)
    t.start()
    try:
        for i in range(16):
            ix.index_batch(corpus.batch(i, 32))
    finally:
        stop.set()
        t.join(timeout=60)
    assert not t.is_alive() and not errors, errors
    final = ix.finalize()  # merge asserts disjoint ordered doc ranges
    assert final.n_docs == 16 * 32
    assert (np.diff(final.doc_ids) > 0).all()
    ix.close()


def test_stress_search_thread_during_concurrent_ingest():
    """A reader thread hammers refresh()+search() while the main thread
    ingests with background merges — every snapshot must be complete and
    consistent (monotonically growing doc count, no exceptions)."""
    cfg = get_arch("lucene-envelope").smoke
    corpus = SyntheticCorpus(TINY, doc_buffer_len=cfg.doc_len)
    ix = DistributedIndexer(cfg=cfg, merge_threads=2)
    stop = threading.Event()
    errors, seen_docs = [], []

    def reader():
        rng = np.random.default_rng(4)
        try:
            while not stop.is_set():
                s = ix.refresh(flush=False)  # only the flushed, live set
                seen_docs.append(s.n_docs)
                if s.n_docs:
                    q = rng.integers(1, 1 << 12, size=3).astype(np.int32)
                    s.search(q, 5)
        except Exception as e:  # surfaced after join
            errors.append(e)

    t = threading.Thread(target=reader)
    t.start()
    try:
        for i in range(10):
            ix.index_batch(corpus.batch(i, 32))
    finally:
        stop.set()
        t.join(timeout=30)
    assert not t.is_alive() and not errors, errors
    assert seen_docs == sorted(seen_docs), "doc count went backwards"
    final = ix.finalize()
    assert final.n_docs == 320
    ix.close()


# ---------------------------------------------------------------------------
# scheduler error paths under a retry policy (ISSUE 7 satellite)
# ---------------------------------------------------------------------------

def test_failing_batch_restores_inputs_while_others_complete(monkeypatch):
    """Two batches in flight, one faults mid-scatter: the failed batch's
    inputs return to their tier intact (every doc still live) while the
    healthy batch's merge installs normally."""
    import errno
    real = merge_segments

    def selective(segs):
        if min(s.doc_ids[0] for s in segs) == 0:   # batch [s0, s1] only
            raise RuntimeError("batch A exploded")
        return real(segs)

    monkeypatch.setattr(merge_mod, "merge_segments", selective)
    drv = MergeDriver(fanout=2)
    sched = ConcurrentMergeScheduler(drv, max_threads=2)
    rng = np.random.default_rng(6)
    segs = _flush_n(drv, 4, rng)          # two tier-0 batches of two
    with pytest.raises(RuntimeError, match="batch A exploded"):
        sched.drain()
    live = drv.live_segments()
    got = np.sort(np.concatenate([s.doc_ids for s in live]))
    want = np.sort(np.concatenate([s.doc_ids for s in segs]))
    assert (got == want).all(), "docs lost by the failed merge"
    assert {s.seg_id for s in segs[:2]} <= {s.seg_id for s in live}
    assert drv.n_merges == 1              # the healthy batch landed
    assert not drv._in_flight
    sched.pool.shutdown(wait=True)


def test_merge_retry_policy_reenqueues_and_converges(monkeypatch):
    """With a retry policy, a faulted merge is re-enqueued with backoff
    instead of parking its error: drain() converges without raising."""
    import errno
    from repro.storage import RetryPolicy
    calls = []

    def flaky(segs):
        calls.append(1)
        if len(calls) <= 2:
            raise OSError(errno.EIO, "merge IO hiccup")
        return merge_segments(segs)

    monkeypatch.setattr(merge_mod, "merge_segments", flaky)
    drv = MergeDriver(fanout=2)
    sched = ConcurrentMergeScheduler(
        drv, max_threads=1,
        retry_policy=RetryPolicy(max_retries=3, base_delay_s=1e-4,
                                 max_delay_s=1e-3))
    rng = np.random.default_rng(7)
    segs = _flush_n(drv, 2, rng)
    sched.drain()                         # heals inside the cap: no raise
    assert drv.n_merges == 1 and len(calls) == 3
    assert sched.merge_retries == 2
    merged = drv.live_segments()
    assert len(merged) == 1
    all_docs = np.sort(np.concatenate([s.doc_ids for s in segs]))
    assert (merged[0].doc_ids == all_docs).all()
    sched.drain()                         # healthy: no stale error either
    sched.close()


def test_merge_retries_exhausted_is_typed_and_restores_inputs(monkeypatch):
    """Past the cap, drain raises the typed MergeRetriesExhausted (last
    failure chained) and the inputs are still safely in their tier."""
    import errno
    from repro.core.merge import MergeRetriesExhausted
    from repro.storage import RetryPolicy

    def boom(segs):
        raise OSError(errno.EIO, "dead controller")

    monkeypatch.setattr(merge_mod, "merge_segments", boom)
    drv = MergeDriver(fanout=2)
    sched = ConcurrentMergeScheduler(
        drv, max_threads=1,
        retry_policy=RetryPolicy(max_retries=2, base_delay_s=1e-4,
                                 max_delay_s=1e-3))
    rng = np.random.default_rng(8)
    segs = _flush_n(drv, 2, rng)
    with pytest.raises(MergeRetriesExhausted) as e:
        sched.drain()
    # 1 try + max_retries backoff re-tries (+ at most one from drain's
    # own leading notify racing the final backoff timer)
    assert e.value.attempts in (3, 4)
    assert isinstance(e.value.__cause__, OSError)
    assert sched.merge_retries == 2       # backoff bounded by the cap
    live = drv.live_segments()            # nothing lost, nothing stuck
    assert {s.seg_id for s in live} == {s.seg_id for s in segs}
    assert not drv._in_flight and drv.n_merges == 0
    sched.pool.shutdown(wait=True)
