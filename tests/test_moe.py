"""MoE dispatch properties (hypothesis): gate-mass conservation without
drops, drop accounting under tight capacity, router load statistics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_arch
from repro.models.moe import capacity, moe_ffn, moe_init


def _cfg(**kw):
    base = get_arch("moonshot-v1-16b-a3b").smoke
    return dataclasses.replace(base, **kw)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10 ** 6), st.sampled_from([1, 2, 4]))
def test_moe_linear_in_gates_no_drops(seed, k):
    """With dropless capacity, the MoE output is the gate-weighted sum of
    per-expert SwiGLUs: scaling all expert weights by c scales outputs
    by ~c (SwiGLU is not linear, but zero weights -> zero output must
    hold exactly)."""
    cfg = _cfg(capacity_factor=16.0, top_k=k)
    key = jax.random.PRNGKey(seed % 2 ** 31)
    params = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 16, cfg.d_model)) * 0.5
    out, aux = moe_ffn(params, x, cfg, jnp.float32)
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 0
    zero = jax.tree.map(jnp.zeros_like, params)
    zero["router"] = params["router"]
    out0, _ = moe_ffn(zero, x, cfg, jnp.float32)
    np.testing.assert_allclose(np.asarray(out0), 0.0, atol=1e-6)


def test_moe_capacity_drops_bounded():
    """With capacity_factor < 1 some assignments MUST drop; output stays
    finite and bounded by the no-drop output's scale."""
    cfg = _cfg(capacity_factor=0.25)
    key = jax.random.PRNGKey(3)
    params = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (4, 32, cfg.d_model))
    out_t, _ = moe_ffn(params, x, cfg, jnp.float32)
    cfg_full = _cfg(capacity_factor=16.0)
    out_f, _ = moe_ffn(params, x, cfg_full, jnp.float32)
    n_t = float(jnp.linalg.norm(out_t))
    n_f = float(jnp.linalg.norm(out_f))
    assert np.isfinite(n_t) and n_t < n_f  # dropped mass strictly reduces


def test_capacity_helper():
    assert capacity(1024, 2, 8, 1.25) >= 1024 * 2 * 1.25 / 8
    assert capacity(8, 1, 64, 1.0) >= 1  # floor


def test_router_aux_encourages_balance():
    """Aux loss is minimal when routing is uniform (Switch lemma)."""
    cfg = _cfg(router_aux_loss=1.0, capacity_factor=16.0)
    key = jax.random.PRNGKey(0)
    params = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 64, cfg.d_model))
    _, aux_rand = moe_ffn(params, x, cfg, jnp.float32)
    # collapse the router onto one expert -> aux must increase
    params2 = dict(params)
    r = np.zeros((cfg.d_model, cfg.n_experts), np.float32)
    r[:, 0] = 10.0
    params2["router"] = jnp.asarray(r)
    _, aux_collapsed = moe_ffn(params2, x, cfg, jnp.float32)
    assert float(aux_collapsed) > float(aux_rand)
